//! Compressed sparse row (CSR) matrix — the sparse sibling of
//! [`super::RowMatrix`].
//!
//! libsvm inputs are overwhelmingly sparse; storing only the nonzeros
//! multiplies the effective bandwidth of every row-wise pass (the DVI
//! scan, the Gram build, the CD gradient sweep) by `1/density`.
//!
//! **Bit-compatibility contract.** Every kernel here reproduces the exact
//! floating-point result of its dense counterpart in [`super`]: the dense
//! 8-way-unrolled `dot` assigns position `j` to accumulator `j % 8` (for
//! `j` below the 8-aligned limit) and sums the ragged tail sequentially,
//! and a zero term is an additive identity — so striping the *nonzeros*
//! into the same accumulators in ascending-index order yields the same
//! partial sums, the same final reduction, and therefore bit-identical
//! screening decisions and solver iterates on sparse and dense storage of
//! the same data. The equivalence suite (`tests/integration_storage.rs`)
//! locks this in end-to-end.

use super::matrix::RowMatrix;

/// CSR sparse matrix: `indptr` (len `rows + 1`) delimits each row's slice
/// of `indices`/`values`; indices are strictly ascending within a row.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from per-row `(col, value)` entry lists (the shape a libsvm
    /// parse produces). Entries may be unordered; duplicate columns keep
    /// the *last* value, matching dense `set` overwrite semantics.
    pub fn from_rows(entries: Vec<Vec<(usize, f64)>>, cols: usize) -> CsrMatrix {
        assert!(cols <= u32::MAX as usize, "column count exceeds u32 index range");
        let rows = entries.len();
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let nnz_hint: usize = entries.iter().map(|r| r.len()).sum();
        let mut indices = Vec::with_capacity(nnz_hint);
        let mut values = Vec::with_capacity(nnz_hint);
        for mut feats in entries {
            feats.sort_by_key(|&(j, _)| j); // stable: file order kept per column
            let mut k = 0;
            while k < feats.len() {
                let (j, mut v) = feats[k];
                assert!(j < cols, "column index {j} out of range (cols = {cols})");
                // last duplicate wins (dense overwrite semantics)
                while k + 1 < feats.len() && feats[k + 1].0 == j {
                    k += 1;
                    v = feats[k].1;
                }
                indices.push(j as u32);
                values.push(v);
                k += 1;
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Compress a dense matrix (drops exact zeros).
    pub fn from_dense(m: &RowMatrix) -> CsrMatrix {
        assert!(m.cols() <= u32::MAX as usize, "column count exceeds u32 index range");
        let (rows, cols) = (m.rows(), m.cols());
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    /// Materialize as dense (the only place sparse storage allocates an
    /// l×n buffer — callers opt in explicitly).
    pub fn to_dense(&self) -> RowMatrix {
        let mut m = RowMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let r = m.row_mut(i);
            for (&j, &v) in idx.iter().zip(val) {
                r[j as usize] = v;
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Cumulative row nonzero counts (len `rows + 1`) — the natural
    /// weight vector for area-balanced sharding of row-wise passes.
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Row i as (indices, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Stored entries in row i.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    /// Element accessor (binary search within the row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (idx, val) = self.row(i);
        match idx.binary_search(&(j as u32)) {
            Ok(k) => val[k],
            Err(_) => 0.0,
        }
    }

    /// out[i] = ⟨row_i, v⟩ — bit-identical to the dense matvec.
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            let (idx, val) = self.row(i);
            *o = striped_sparse_dot(idx, val, v, self.cols);
        }
    }

    /// out = Mᵀ v — bit-identical to the dense t_matvec (which skips
    /// zero coefficients and axpy-accumulates rows in ascending order).
    pub fn t_matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                let (idx, val) = self.row(i);
                sparse_axpy(vi, idx, val, out);
            }
        }
    }

    /// Squared norm of every row — bit-identical to the dense version.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                let (idx, val) = self.row(i);
                striped_sparse_self_dot(idx, val, self.cols)
            })
            .collect()
    }

    /// Gram entry G[i,j] = ⟨row_i, row_j⟩ — bit-identical to the dense
    /// dot (zero products are additive identities; the intersection merge
    /// feeds the same stripe accumulators in the same order).
    pub fn gram(&self, i: usize, j: usize) -> f64 {
        let (ai, av) = self.row(i);
        let (bi, bv) = self.row(j);
        striped_sparse_sparse_dot(ai, av, bi, bv, self.cols)
    }

    /// Sub-matrix of the given rows (copies).
    pub fn select_rows(&self, idx: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for &i in idx {
            let (ri, rv) = self.row(i);
            indices.extend_from_slice(ri);
            values.extend_from_slice(rv);
            indptr.push(indices.len());
        }
        CsrMatrix { rows: idx.len(), cols: self.cols, indptr, indices, values }
    }

    /// Scale row i in place by s.
    pub fn scale_row(&mut self, i: usize, s: f64) {
        let (a, b) = (self.indptr[i], self.indptr[i + 1]);
        for v in &mut self.values[a..b] {
            *v *= s;
        }
    }

    /// Scale column j in place by s (sparsity-preserving).
    pub fn scale_col(&mut self, j: usize, s: f64) {
        let j = j as u32;
        for (idx, v) in self.indices.iter().zip(self.values.iter_mut()) {
            if *idx == j {
                *v *= s;
            }
        }
    }

    /// Scale every column j by `factors[j]` in one pass over the stored
    /// values (sparsity-preserving; used by scale-only standardization of
    /// sparse datasets).
    pub fn scale_cols(&mut self, factors: &[f64]) {
        assert_eq!(factors.len(), self.cols, "one factor per column");
        for (idx, v) in self.indices.iter().zip(self.values.iter_mut()) {
            *v *= factors[*idx as usize];
        }
    }

    /// New matrix with the same sparsity pattern and transformed values;
    /// `f(row, col, value)` is called per stored entry. This is how an
    /// [`crate::problem::Instance`] builds Z = −yᵢ·xᵢ without densifying.
    pub fn map_values(&self, mut f: impl FnMut(usize, usize, f64) -> f64) -> CsrMatrix {
        let mut values = Vec::with_capacity(self.values.len());
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                values.push(f(i, j as usize, v));
            }
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values,
        }
    }
}

/// ⟨sparse row, dense y⟩ striped into the dense `dot`'s accumulator
/// layout: position `j` below the 8-aligned limit feeds accumulator
/// `j % 8`, the ragged tail sums sequentially, and the final reduction
/// tree matches — bit-identical to `linalg::dot(dense_row, y)`.
#[inline]
pub fn striped_sparse_dot(indices: &[u32], values: &[f64], y: &[f64], cols: usize) -> f64 {
    debug_assert_eq!(y.len(), cols);
    let limit = (cols / 8) * 8;
    let mut s = [0.0f64; 8];
    let mut tail = 0.0;
    for (&j, &v) in indices.iter().zip(values) {
        let j = j as usize;
        if j < limit {
            s[j % 8] += v * y[j];
        } else {
            tail += v * y[j];
        }
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// ⟨row, row⟩ with the same striping — bit-identical to
/// `linalg::dot(dense_row, dense_row)`.
#[inline]
pub fn striped_sparse_self_dot(indices: &[u32], values: &[f64], cols: usize) -> f64 {
    let limit = (cols / 8) * 8;
    let mut s = [0.0f64; 8];
    let mut tail = 0.0;
    for (&j, &v) in indices.iter().zip(values) {
        if (j as usize) < limit {
            s[j as usize % 8] += v * v;
        } else {
            tail += v * v;
        }
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// ⟨sparse a, sparse b⟩ over the index intersection (ascending merge),
/// striped identically — bit-identical to the dense Gram dot.
#[inline]
pub fn striped_sparse_sparse_dot(
    ai: &[u32],
    av: &[f64],
    bi: &[u32],
    bv: &[f64],
    cols: usize,
) -> f64 {
    let limit = (cols / 8) * 8;
    let mut s = [0.0f64; 8];
    let mut tail = 0.0;
    let (mut p, mut q) = (0usize, 0usize);
    while p < ai.len() && q < bi.len() {
        match ai[p].cmp(&bi[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                let j = ai[p] as usize;
                let prod = av[p] * bv[q];
                if j < limit {
                    s[j % 8] += prod;
                } else {
                    tail += prod;
                }
                p += 1;
                q += 1;
            }
        }
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// out += a·row for a sparse row — same per-component additions (in
/// ascending index order) as the dense `axpy`, which adds an exact zero
/// everywhere the sparse row has no entry.
#[inline]
pub fn sparse_axpy(a: f64, indices: &[u32], values: &[f64], out: &mut [f64]) {
    for (&j, &v) in indices.iter().zip(values) {
        out[j as usize] += a * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    fn sample() -> CsrMatrix {
        // 3×5: [[1,0,2,0,0],[0,0,0,0,3],[0,-1,0,4,0]]
        CsrMatrix::from_rows(
            vec![
                vec![(0, 1.0), (2, 2.0)],
                vec![(4, 3.0)],
                vec![(3, 4.0), (1, -1.0)], // unordered on purpose
            ],
            5,
        )
    }

    #[test]
    fn construction_sorts_and_dedups() {
        let m = sample();
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 5);
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(2), (&[1u32, 3][..], &[-1.0, 4.0][..]));
        // duplicate column: last value wins (dense overwrite semantics)
        let d = CsrMatrix::from_rows(vec![vec![(1, 5.0), (1, 7.0)]], 3);
        assert_eq!(d.nnz(), 1);
        assert_eq!(d.get(0, 1), 7.0);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(d.row(0), &[1.0, 0.0, 2.0, 0.0, 0.0]);
        assert_eq!(CsrMatrix::from_dense(&d), m);
    }

    #[test]
    fn get_and_row_nnz() {
        let m = sample();
        assert_eq!(m.get(0, 2), 2.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.row_nnz(1), 1);
        assert_eq!(m.indptr(), &[0, 2, 3, 5]);
    }

    #[test]
    fn ops_bit_identical_to_dense() {
        // randomized wide matrix so the 8-aligned limit and ragged tail
        // are both exercised
        let mut rng = crate::data::Rng::new(42);
        let (l, n) = (17usize, 27usize);
        let mut entries = Vec::new();
        for _ in 0..l {
            let mut row = Vec::new();
            for j in 0..n {
                if rng.bernoulli(0.3) {
                    row.push((j, rng.normal(0.0, 1.0)));
                }
            }
            entries.push(row);
        }
        let sp = CsrMatrix::from_rows(entries, n);
        let de = sp.to_dense();

        let v: Vec<f64> = (0..n).map(|j| (j as f64 * 0.7).sin()).collect();
        let (mut a, mut b) = (vec![0.0; l], vec![0.0; l]);
        sp.matvec(&v, &mut a);
        de.matvec(&v, &mut b);
        assert_eq!(a, b, "matvec must be bit-identical");

        let w: Vec<f64> = (0..l).map(|i| if i % 3 == 0 { 0.0 } else { (i as f64).cos() }).collect();
        let (mut ua, mut ub) = (vec![0.0; n], vec![0.0; n]);
        sp.t_matvec(&w, &mut ua);
        de.t_matvec(&w, &mut ub);
        assert_eq!(ua, ub, "t_matvec must be bit-identical");

        assert_eq!(sp.row_norms_sq(), de.row_norms_sq(), "row norms must be bit-identical");
        for i in 0..l {
            for j in 0..l {
                assert_eq!(sp.gram(i, j), de.gram(i, j), "gram({i},{j})");
            }
        }
        for i in 0..l {
            let (idx, val) = sp.row(i);
            assert_eq!(
                striped_sparse_dot(idx, val, &v, n),
                linalg::dot(de.row(i), &v),
                "row dot {i}"
            );
        }
    }

    #[test]
    fn select_and_scale() {
        let mut m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.get(0, 3), 4.0);
        assert_eq!(s.get(1, 0), 1.0);
        m.scale_row(0, -2.0);
        assert_eq!(m.get(0, 2), -4.0);
        m.scale_col(4, 0.5);
        assert_eq!(m.get(1, 4), 1.5);
        m.scale_cols(&[2.0, 1.0, 1.0, 1.0, 2.0]);
        assert_eq!(m.get(0, 0), -4.0);
        assert_eq!(m.get(1, 4), 3.0);
    }

    #[test]
    fn map_values_preserves_pattern() {
        let m = sample();
        let neg = m.map_values(|_, _, v| -v);
        assert_eq!(neg.indptr(), m.indptr());
        assert_eq!(neg.get(2, 3), -4.0);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_index() {
        CsrMatrix::from_rows(vec![vec![(5, 1.0)]], 5);
    }
}
