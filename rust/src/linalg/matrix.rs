//! Row-major dense matrix with the operations the solver and screening
//! rules need: row access, matvec in both orientations, row norms, and a
//! Gram-column helper for the dual coordinate-descent inner loop.

use super::{axpy, dot};

/// Dense row-major matrix (l rows × n cols). Rows are data instances.
#[derive(Clone, Debug, PartialEq)]
pub struct RowMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl RowMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        RowMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer (length must equal rows·cols).
    pub fn from_flat(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "flat buffer size mismatch");
        RowMatrix { rows, cols, data }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        RowMatrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row i.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major view.
    #[inline]
    pub fn flat(&self) -> &[f64] {
        &self.data
    }

    /// out[i] = ⟨row_i, v⟩ — the screening scan direction (l·n flops).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot(self.row(i), v);
        }
    }

    /// out = Σ_i v[i]·row_i, i.e. out = Mᵀ v (n-vector). Used for
    /// u = Zᵀθ and the Lemma-4 offset vector.
    pub fn t_matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                axpy(vi, self.row(i), out);
            }
        }
    }

    /// Squared norm of every row.
    pub fn row_norms_sq(&self) -> Vec<f64> {
        (0..self.rows).map(|i| dot(self.row(i), self.row(i))).collect()
    }

    /// Sub-matrix of the given rows (copies).
    pub fn select_rows(&self, idx: &[usize]) -> RowMatrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        RowMatrix { rows: idx.len(), cols: self.cols, data }
    }

    /// Gram entry G[i,j] = ⟨row_i, row_j⟩.
    #[inline]
    pub fn gram(&self, i: usize, j: usize) -> f64 {
        dot(self.row(i), self.row(j))
    }

    /// Append a row.
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Scale row i in place by s.
    pub fn scale_row(&mut self, i: usize, s: f64) {
        for v in self.row_mut(i) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m23() -> RowMatrix {
        RowMatrix::from_flat(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn accessors() {
        let m = m23();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn from_fn_layout() {
        let m = RowMatrix::from_fn(2, 2, |i, j| (10 * i + j) as f64);
        assert_eq!(m.flat(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn matvec_both_ways() {
        let m = m23();
        let mut out = vec![0.0; 2];
        m.matvec(&[1.0, 0.0, -1.0], &mut out);
        assert_eq!(out, vec![-2.0, -2.0]);

        let mut out2 = vec![0.0; 3];
        m.t_matvec(&[1.0, 2.0], &mut out2);
        assert_eq!(out2, vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn t_matvec_skips_zeros() {
        let m = m23();
        let mut out = vec![0.0; 3];
        m.t_matvec(&[0.0, 1.0], &mut out);
        assert_eq!(out, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn row_norms() {
        let m = m23();
        let n = m.row_norms_sq();
        assert_eq!(n, vec![14.0, 77.0]);
    }

    #[test]
    fn select_and_push() {
        let m = m23();
        let s = m.select_rows(&[1]);
        assert_eq!(s.rows(), 1);
        assert_eq!(s.row(0), &[4.0, 5.0, 6.0]);
        let mut m2 = s;
        m2.push_row(&[7.0, 8.0, 9.0]);
        assert_eq!(m2.rows(), 2);
        assert_eq!(m2.gram(0, 1), 4.0 * 7.0 + 5.0 * 8.0 + 6.0 * 9.0);
    }

    #[test]
    fn scale_row_works() {
        let mut m = m23();
        m.scale_row(0, -1.0);
        assert_eq!(m.row(0), &[-1.0, -2.0, -3.0]);
    }

    #[test]
    #[should_panic]
    fn from_flat_size_mismatch_panics() {
        RowMatrix::from_flat(2, 2, vec![1.0; 5]);
    }
}
