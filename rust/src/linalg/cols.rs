//! Column-access mirror of [`super::Rows`]: the feature-axis sibling of
//! the row layer, enabling write-disjoint column sharding of n-dimensional
//! accumulations (u = Zᵀθ reconstruction, w extraction) on wide data.
//!
//! **Bit-compatibility contract.** `Rows::t_matvec` zeroes the output,
//! skips rows with a zero coefficient, and axpy-accumulates the surviving
//! rows in ascending row order — so each output component `out[j]` is an
//! *independent* sequential sum over ascending rows. A column shard that
//! owns a contiguous slab of components and replays exactly that per-
//! component order (ascending rows, same zero-coefficient skip, same
//! stored-entry set) produces bit-identical results for its slab, and
//! slabs never overlap, so the sharded reconstruction equals the serial
//! row-major one at every thread count. `tests` below and
//! `tests/integration_cols.rs` lock this end-to-end.
//!
//! A single *dot product* cannot be split across column slabs without
//! changing the floating-point reduction order, so kernels that need whole
//! dots (the θ-form Gram build) shard over *output* columns and compute
//! each dot with the existing row kernels — see
//! [`crate::screening::Dvi::new_theta_axis`].

use super::csr::CsrMatrix;
use super::matrix::RowMatrix;
use super::rows::Rows;

/// Which data axis the n-dimensional hot paths shard over. `Rows` is the
/// historical row-major path (serial n-length accumulators); `Cols` shards
/// disjoint column slabs of the lazily built mirror across the solver
/// pool; `Auto` picks per instance from the cached shape/nnz balance (see
/// [`crate::problem::Instance::pick_axis`]). The axis never changes any
/// result byte — it only partitions work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShardAxis {
    Rows,
    Cols,
    Auto,
}

impl ShardAxis {
    pub fn parse(s: &str) -> Option<ShardAxis> {
        match s {
            "rows" => Some(ShardAxis::Rows),
            "cols" => Some(ShardAxis::Cols),
            "auto" => Some(ShardAxis::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ShardAxis::Rows => "rows",
            ShardAxis::Cols => "cols",
            ShardAxis::Auto => "auto",
        }
    }
}

impl Default for ShardAxis {
    fn default() -> Self {
        ShardAxis::Rows
    }
}

/// Dense column-major matrix: column j is the contiguous slice
/// `data[j·rows .. (j+1)·rows]`. Mirrors a [`RowMatrix`] including its
/// explicit zeros, so a column sweep replays every `vi·0.0` term the dense
/// row axpy performs.
#[derive(Clone, Debug, PartialEq)]
pub struct ColMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl ColMatrix {
    /// Transpose-copy a row-major matrix into column-major layout.
    pub fn from_row_major(m: &RowMatrix) -> ColMatrix {
        let (rows, cols) = (m.rows(), m.cols());
        let mut data = vec![0.0f64; rows * cols];
        for i in 0..rows {
            let r = m.row(i);
            for j in 0..cols {
                data[j * rows + i] = r[j];
            }
        }
        ColMatrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Column j as a contiguous slice (length `rows`).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }
}

/// Compressed sparse column (CSC) matrix — the transpose layout of
/// [`CsrMatrix`]. `colptr` (len `cols + 1`) delimits each column's slice
/// of `indices`/`values`; row indices are strictly ascending within a
/// column (guaranteed by the counting-sort construction, which visits CSR
/// rows in ascending order).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    colptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Counting-sort transposition of a CSR matrix: one pass counts the
    /// per-column entries, a prefix sum turns counts into `colptr`, and a
    /// second pass scatters each stored entry into its column slot. Rows
    /// are visited ascending, so each column's row indices come out
    /// ascending — the order the bit-compatibility contract requires.
    pub fn from_csr(m: &CsrMatrix) -> CscMatrix {
        assert!(m.rows() <= u32::MAX as usize, "row count exceeds u32 index range");
        let (rows, cols) = (m.rows(), m.cols());
        let mut colptr = vec![0usize; cols + 1];
        for i in 0..rows {
            let (idx, _) = m.row(i);
            for &j in idx {
                colptr[j as usize + 1] += 1;
            }
        }
        for j in 0..cols {
            colptr[j + 1] += colptr[j];
        }
        let nnz = m.nnz();
        let mut next = colptr.clone();
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0f64; nnz];
        for i in 0..rows {
            let (idx, val) = m.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                let p = next[j as usize];
                indices[p] = i as u32;
                values[p] = v;
                next[j as usize] = p + 1;
            }
        }
        CscMatrix { rows, cols, colptr, indices, values }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored-entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Cumulative column nonzero counts (len `cols + 1`) — the natural
    /// weight vector for nnz-balanced column slabs.
    #[inline]
    pub fn colptr(&self) -> &[usize] {
        &self.colptr
    }

    /// Column j as (ascending row indices, values) slices.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.colptr[j], self.colptr[j + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }
}

/// A column-access mirror in either dense (column-major) or CSC storage,
/// always matching the storage of the [`Rows`] it was built from.
#[derive(Clone, Debug, PartialEq)]
pub enum Cols {
    Dense(ColMatrix),
    Sparse(CscMatrix),
}

impl Cols {
    /// Build the mirror for the given row matrix (dense → column-major
    /// dense, CSR → CSC). O(l·n) / O(nnz) one-time cost; the instance
    /// layer caches the result alongside the nnz prefix.
    pub fn from_rows(z: &Rows) -> Cols {
        match z {
            Rows::Dense(m) => Cols::Dense(ColMatrix::from_row_major(m)),
            Rows::Sparse(m) => Cols::Sparse(CscMatrix::from_csr(m)),
        }
    }

    /// Sample count l (length of each column).
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Cols::Dense(m) => m.rows(),
            Cols::Sparse(m) => m.rows(),
        }
    }

    /// Feature dimension n (number of columns).
    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Cols::Dense(m) => m.cols(),
            Cols::Sparse(m) => m.cols(),
        }
    }

    pub fn storage_name(&self) -> &'static str {
        match self {
            Cols::Dense(_) => "dense",
            Cols::Sparse(_) => "csc",
        }
    }

    /// Borrow column j as a storage-polymorphic view.
    #[inline]
    pub fn col(&self, j: usize) -> ColView<'_> {
        match self {
            Cols::Dense(m) => ColView::Dense(m.col(j)),
            Cols::Sparse(m) => {
                let (indices, values) = m.col(j);
                ColView::Sparse { rows: m.rows(), indices, values }
            }
        }
    }

    /// Mirror buffer footprint in bytes. Identical to
    /// [`Cols::projected_bytes`] for the same shape/nnz, so the instance
    /// cache can charge the mirror *before* it is lazily built.
    pub fn approx_bytes(&self) -> usize {
        match self {
            Cols::Dense(m) => Cols::projected_bytes(false, m.rows(), m.cols(), m.rows() * m.cols()),
            Cols::Sparse(m) => Cols::projected_bytes(true, m.rows(), m.cols(), m.nnz()),
        }
    }

    /// Mirror size computable from shape/nnz alone, *without* building the
    /// mirror: the dense column-major payload is `l·n·8`, CSC carries
    /// `nnz·(8 + 4)` values+indices plus the `(n + 1)·8` colptr. The LRU
    /// charge in `Instance::approx_bytes` uses this projection so lazily
    /// building the mirror never changes an already-admitted entry's cost.
    pub fn projected_bytes(sparse: bool, rows: usize, cols: usize, nnz: usize) -> usize {
        if sparse {
            nnz * (8 + 4) + (cols + 1) * 8
        } else {
            rows * cols * 8
        }
    }

    /// Column-slab boundaries (len `shards + 1`, starting at 0, ending at
    /// n) carrying near-equal work: uniform column counts for dense,
    /// nnz-balanced via `colptr` for CSC. Boundaries only partition work —
    /// the slab kernel is bit-identical for any split.
    pub fn balanced_bounds(&self, shards: usize) -> Vec<usize> {
        let ranges = match self {
            Cols::Dense(m) => super::par::shard_ranges(m.cols(), shards),
            Cols::Sparse(m) => super::par::cumulative_ranges(m.colptr(), shards),
        };
        let mut bounds = Vec::with_capacity(ranges.len() + 1);
        bounds.push(0usize);
        bounds.extend(ranges.iter().map(|r| r.end));
        bounds
    }

    /// out[k] = Σᵢ v[i]·M[i][j0+k] for the column slab `j0..j1`, replaying
    /// `Rows::t_matvec`'s per-component accumulation exactly: rows visited
    /// ascending, rows with `v[i] == 0.0` skipped (both storages skip
    /// them), and for dense every surviving term — zeros included — is
    /// added, just as the dense row axpy does. `out` must have length
    /// `j1 − j0`.
    pub fn t_matvec_slab(&self, v: &[f64], j0: usize, j1: usize, out: &mut [f64]) {
        assert_eq!(out.len(), j1 - j0, "slab output length mismatch");
        match self {
            Cols::Dense(m) => {
                assert_eq!(v.len(), m.rows());
                for (k, o) in out.iter_mut().enumerate() {
                    let col = m.col(j0 + k);
                    let mut s = 0.0f64;
                    for (i, &vi) in v.iter().enumerate() {
                        if vi != 0.0 {
                            s += vi * col[i];
                        }
                    }
                    *o = s;
                }
            }
            Cols::Sparse(m) => {
                assert_eq!(v.len(), m.rows());
                for (k, o) in out.iter_mut().enumerate() {
                    let (idx, val) = m.col(j0 + k);
                    let mut s = 0.0f64;
                    for (&i, &x) in idx.iter().zip(val) {
                        let vi = v[i as usize];
                        if vi != 0.0 {
                            s += vi * x;
                        }
                    }
                    *o = s;
                }
            }
        }
    }

    /// Like [`Cols::t_matvec_slab`] but WITHOUT the zero-coefficient skip:
    /// every row contributes unconditionally, replaying an *unconditional*
    /// ascending-row axpy accumulation (`RowView::axpy_into` in a plain
    /// `for k in 0..rows` loop — the model layer's support-row replay).
    /// The two kernels agree whenever `v` contains no exact zeros; this
    /// one stays exact even when it does.
    pub fn accum_slab(&self, v: &[f64], j0: usize, j1: usize, out: &mut [f64]) {
        assert_eq!(out.len(), j1 - j0, "slab output length mismatch");
        match self {
            Cols::Dense(m) => {
                assert_eq!(v.len(), m.rows());
                for (k, o) in out.iter_mut().enumerate() {
                    let col = m.col(j0 + k);
                    let mut s = 0.0f64;
                    for (i, &vi) in v.iter().enumerate() {
                        s += vi * col[i];
                    }
                    *o = s;
                }
            }
            Cols::Sparse(m) => {
                assert_eq!(v.len(), m.rows());
                for (k, o) in out.iter_mut().enumerate() {
                    let (idx, val) = m.col(j0 + k);
                    let mut s = 0.0f64;
                    for (&i, &x) in idx.iter().zip(val) {
                        s += v[i as usize] * x;
                    }
                    *o = s;
                }
            }
        }
    }
}

/// Borrowed view of one column in either storage.
#[derive(Clone, Copy, Debug)]
pub enum ColView<'a> {
    Dense(&'a [f64]),
    Sparse {
        rows: usize,
        indices: &'a [u32],
        values: &'a [f64],
    },
}

impl<'a> ColView<'a> {
    /// Logical length (the sample count l, both storages).
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            ColView::Dense(c) => c.len(),
            ColView::Sparse { rows, .. } => *rows,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored-entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        match self {
            ColView::Dense(c) => c.len(),
            ColView::Sparse { values, .. } => values.len(),
        }
    }

    /// Densified copy (tests and cold paths only).
    pub fn to_vec(&self) -> Vec<f64> {
        match self {
            ColView::Dense(c) => c.to_vec(),
            ColView::Sparse { rows, indices, values } => {
                let mut out = vec![0.0; *rows];
                for (&i, &v) in indices.iter().zip(*values) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Storage;

    fn random_rows(l: usize, n: usize, density: f64, seed: u64) -> (Rows, Rows) {
        let mut rng = crate::data::Rng::new(seed);
        let mut entries = Vec::new();
        for _ in 0..l {
            let mut row = Vec::new();
            for j in 0..n {
                if rng.bernoulli(density) {
                    row.push((j, rng.normal(0.0, 1.0)));
                }
            }
            entries.push(row);
        }
        let sp = CsrMatrix::from_rows(entries, n);
        let de = Rows::Dense(sp.to_dense());
        (de, Rows::Sparse(sp))
    }

    #[test]
    fn shard_axis_parse_and_names() {
        assert_eq!(ShardAxis::parse("rows"), Some(ShardAxis::Rows));
        assert_eq!(ShardAxis::parse("cols"), Some(ShardAxis::Cols));
        assert_eq!(ShardAxis::parse("auto"), Some(ShardAxis::Auto));
        assert_eq!(ShardAxis::parse("columns"), None);
        assert_eq!(ShardAxis::Cols.name(), "cols");
        assert_eq!(ShardAxis::default(), ShardAxis::Rows);
    }

    #[test]
    fn csc_mirrors_csr_with_ascending_rows() {
        let (_, sp) = random_rows(13, 21, 0.3, 7);
        let Rows::Sparse(csr) = &sp else { unreachable!() };
        let csc = CscMatrix::from_csr(csr);
        assert_eq!(csc.rows(), 13);
        assert_eq!(csc.cols(), 21);
        assert_eq!(csc.nnz(), csr.nnz());
        for j in 0..21 {
            let (idx, val) = csc.col(j);
            // ascending row order within each column
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "col {j} rows not ascending");
            for (&i, &v) in idx.iter().zip(val) {
                assert_eq!(csr.get(i as usize, j), v, "entry ({i},{j})");
            }
        }
        // every stored entry present
        let col_nnz: usize = (0..21).map(|j| csc.col(j).0.len()).sum();
        assert_eq!(col_nnz, csr.nnz());
    }

    #[test]
    fn dense_mirror_is_exact_transpose() {
        let (de, _) = random_rows(9, 11, 0.8, 3);
        let cols = Cols::from_rows(&de);
        assert_eq!(cols.storage_name(), "dense");
        for j in 0..11 {
            let col = cols.col(j).to_vec();
            for i in 0..9 {
                assert_eq!(col[i], de.get(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn slab_t_matvec_bit_identical_to_rows() {
        // dimensions straddling the 8-aligned limit, with zero coefficients
        for (l, n, density) in [(17usize, 27usize, 0.3), (5, 40, 0.9), (23, 8, 0.5)] {
            let (de, sp) = random_rows(l, n, density, 1000 + n as u64);
            let v: Vec<f64> =
                (0..l).map(|i| if i % 4 == 0 { 0.0 } else { (i as f64 * 0.31).sin() }).collect();
            for z in [&de, &sp] {
                let mut want = vec![0.0; n];
                z.t_matvec(&v, &mut want);
                let cols = Cols::from_rows(z);
                // whole-range slab
                let mut got = vec![0.0; n];
                cols.t_matvec_slab(&v, 0, n, &mut got);
                assert_eq!(got, want, "{} whole slab", z.storage_name());
                // arbitrary multi-slab splits must concatenate identically
                for shards in [2usize, 3, 5] {
                    let bounds = cols.balanced_bounds(shards);
                    assert_eq!(*bounds.first().unwrap(), 0);
                    assert_eq!(*bounds.last().unwrap(), n);
                    let mut got = vec![0.0; n];
                    for w in bounds.windows(2) {
                        let (a, b) = (w[0], w[1]);
                        cols.t_matvec_slab(&v, a, b, &mut got[a..b]);
                    }
                    assert_eq!(got, want, "{} {shards}-slab", z.storage_name());
                }
            }
        }
    }

    #[test]
    fn accum_slab_replays_unconditional_axpy() {
        let (de, sp) = random_rows(14, 19, 0.4, 77);
        // v with exact zeros: accum must keep their ±0.0 contributions,
        // exactly like an unconditional ascending-row axpy replay
        let v: Vec<f64> =
            (0..14).map(|i| if i % 3 == 0 { 0.0 } else { -(i as f64) * 0.09 }).collect();
        for z in [&de, &sp] {
            let mut want = vec![0.0; 19];
            for (i, &vi) in v.iter().enumerate() {
                z.row(i).axpy_into(vi, &mut want);
            }
            let cols = Cols::from_rows(z);
            let mut got = vec![0.0; 19];
            for w in cols.balanced_bounds(3).windows(2) {
                cols.accum_slab(&v, w[0], w[1], &mut got[w[0]..w[1]]);
            }
            assert_eq!(got, want, "{}", z.storage_name());
        }
    }

    #[test]
    fn approx_bytes_matches_projection() {
        let (de, sp) = random_rows(12, 30, 0.25, 11);
        let dc = Cols::from_rows(&de);
        assert_eq!(dc.approx_bytes(), Cols::projected_bytes(false, 12, 30, 12 * 30));
        assert_eq!(dc.approx_bytes(), 12 * 30 * 8);
        let sc = Cols::from_rows(&sp);
        assert_eq!(sc.approx_bytes(), Cols::projected_bytes(true, 12, 30, sp.nnz()));
        assert_eq!(sc.approx_bytes(), sp.nnz() * 12 + 31 * 8);
    }

    #[test]
    fn mirror_roundtrips_through_storage_conversion() {
        let (de, sp) = random_rows(10, 16, 0.4, 21);
        // the mirror of the CSR form and the CSR-ification of the dense
        // mirror agree entry-wise
        let mc = Cols::from_rows(&sp);
        let md = Cols::from_rows(&de.clone().into_storage(Storage::Dense));
        for j in 0..16 {
            assert_eq!(mc.col(j).to_vec(), md.col(j).to_vec(), "col {j}");
        }
        assert_eq!(mc.rows(), md.rows());
        assert_eq!(mc.cols(), md.cols());
    }
}
