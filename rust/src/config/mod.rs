//! Configuration system.
//!
//! Offline build ⇒ no serde/toml crates, so this module implements a small
//! TOML-subset parser ([`toml`]) plus the typed configuration structs the
//! launcher consumes ([`experiment`]). Supported TOML subset: `[section]`
//! and `[section.sub]` headers, `key = value` with strings, integers,
//! floats, booleans, and homogeneous inline arrays — which covers every
//! config this framework ships.

pub mod experiment;
pub mod json;
pub mod toml;

pub use experiment::{CdMode, ExperimentConfig, GridConfig, RunConfig, SolverConfig};
pub use crate::linalg::ShardAxis;
pub use json::{parse_json, Json, JsonError};
pub use toml::{parse_str, TomlError, Value};
