//! Minimal TOML-subset parser (offline build — no external crates).
//!
//! Supports:
//! * `[section]` / `[section.subsection]` headers (arbitrary dotted depth);
//! * `key = value` pairs with string, integer, float, boolean values;
//! * homogeneous inline arrays `[1, 2, 3]` / `["a", "b"]`;
//! * `#` comments and blank lines;
//! * dotted keys resolve into a flat map keyed `section.sub.key`.
//!
//! Not supported (rejected with an error rather than mis-parsed):
//! multi-line strings, datetimes, inline tables, arrays of tables.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`C = 10` is a valid float).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError { line, msg: msg.into() }
}

/// Parse a config document into a flat `section.key → Value` map.
pub fn parse_str(src: &str) -> Result<BTreeMap<String, Value>, TomlError> {
    let mut map = BTreeMap::new();
    let mut prefix = String::new();
    for (i, raw) in src.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let inner = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if inner.is_empty() || inner.starts_with('[') {
                return Err(err(lineno, "bad section header (arrays of tables unsupported)"));
            }
            validate_key_path(inner).map_err(|m| err(lineno, m))?;
            prefix = inner.to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = key.trim();
        validate_key_path(key).map_err(|m| err(lineno, m))?;
        let full = if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
        let value = parse_value(val.trim()).map_err(|m| err(lineno, m))?;
        if map.insert(full.clone(), value).is_some() {
            return Err(err(lineno, format!("duplicate key `{full}`")));
        }
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (idx, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

fn validate_key_path(path: &str) -> Result<(), String> {
    for seg in path.split('.') {
        if seg.is_empty() {
            return Err("empty key segment".into());
        }
        if !seg.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') {
            return Err(format!("invalid key segment `{seg}`"));
        }
    }
    Ok(())
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner)? {
            items.push(parse_value(part.trim())?);
        }
        let homogeneous = items
            .windows(2)
            .all(|w| std::mem::discriminant(&w[0]) == std::mem::discriminant(&w[1]));
        if !homogeneous {
            return Err("heterogeneous arrays unsupported".into());
        }
        return Ok(Value::Array(items));
    }
    // numbers: int if no '.', 'e', or inf/nan marker
    let is_floatish = s.contains('.') || s.contains('e') || s.contains('E') || s == "inf" || s == "-inf";
    if is_floatish {
        s.parse::<f64>().map(Value::Float).map_err(|e| format!("bad float `{s}`: {e}"))
    } else {
        s.parse::<i64>().map(Value::Int).map_err(|e| format!("bad number `{s}`: {e}"))
    }
}

/// Split array items at top-level commas (strings may contain commas).
fn split_array_items(s: &str) -> Result<Vec<&str>, String> {
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (idx, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..idx]);
                start = idx + 1;
            }
            '[' | ']' if !in_str => return Err("nested arrays unsupported".into()),
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    out.push(&s[start..]);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let doc = r#"
# top comment
name = "run1"
steps = 100

[solver]
tol = 1e-6
max_iter = 5000
shrink = true

[grid.c]
lo = 0.01
hi = 10.0
"#;
        let m = parse_str(doc).unwrap();
        assert_eq!(m["name"].as_str(), Some("run1"));
        assert_eq!(m["steps"].as_int(), Some(100));
        assert_eq!(m["solver.tol"].as_float(), Some(1e-6));
        assert_eq!(m["solver.shrink"].as_bool(), Some(true));
        assert_eq!(m["grid.c.lo"].as_float(), Some(0.01));
    }

    #[test]
    fn arrays() {
        let m = parse_str("xs = [1, 2, 3]\nys = [1.5, 2.5]\nnames = [\"a\", \"b\"]").unwrap();
        let xs = m["xs"].as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_int(), Some(3));
        assert_eq!(m["ys"].as_array().unwrap()[1].as_float(), Some(2.5));
        assert_eq!(m["names"].as_array().unwrap()[0].as_str(), Some("a"));
    }

    #[test]
    fn empty_array_and_comment_in_string() {
        let m = parse_str("xs = []\ns = \"a # not comment\" # real comment").unwrap();
        assert_eq!(m["xs"].as_array().unwrap().len(), 0);
        assert_eq!(m["s"].as_str(), Some("a # not comment"));
    }

    #[test]
    fn int_vs_float() {
        let m = parse_str("a = 3\nb = 3.0\nc = 1e2\nd = -7").unwrap();
        assert_eq!(m["a"], Value::Int(3));
        assert_eq!(m["b"], Value::Float(3.0));
        assert_eq!(m["c"], Value::Float(100.0));
        assert_eq!(m["d"], Value::Int(-7));
        // as_float accepts ints
        assert_eq!(m["a"].as_float(), Some(3.0));
    }

    #[test]
    fn errors() {
        assert!(parse_str("[unclosed").is_err());
        assert!(parse_str("x 3").is_err());
        assert!(parse_str("x = ").is_err());
        assert!(parse_str("x = \"unterminated").is_err());
        assert!(parse_str("x = [1, \"a\"]").is_err()); // heterogeneous
        assert!(parse_str("x = [[1]]").is_err()); // nested
        assert!(parse_str("a = 1\na = 2").is_err()); // duplicate
        assert!(parse_str("bad key = 1").is_err());
    }

    #[test]
    fn error_reports_line() {
        let e = parse_str("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
