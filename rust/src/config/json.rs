//! Minimal JSON parser (offline build — no serde). Covers the full JSON
//! grammar except: no `\u` escapes beyond BMP passthrough, numbers parse
//! as f64/i64. Used for the artifact manifest and coordinator wire
//! messages.

use std::collections::BTreeMap;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Json::Float(f) => Some(*f),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Float(f) => {
                if f.is_finite() {
                    // keep a dot so it round-trips as float
                    let s = format!("{f}");
                    out.push_str(&s);
                    if !s.contains('.') && !s.contains('e') {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse error.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document.
pub fn parse_json(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if is_float {
            s.parse::<f64>()
                .map(Json::Float)
                .map_err(|e| self.err(format!("bad number `{s}`: {e}")))
        } else {
            s.parse::<i64>()
                .map(Json::Int)
                .map_err(|e| self.err(format!("bad number `{s}`: {e}")))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let src = r#"{
            "version": 1,
            "dtype": "f32",
            "guard_eps": 1e-5,
            "buckets": [
                {"l": 2048, "n": 8, "file": "dvi_screen_2048x8.hlo.txt"},
                {"l": 8192, "n": 32, "file": "dvi_screen_8192x32.hlo.txt"}
            ]
        }"#;
        let j = parse_json(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_int(), Some(1));
        assert_eq!(j.get("dtype").unwrap().as_str(), Some("f32"));
        assert!((j.get("guard_eps").unwrap().as_float().unwrap() - 1e-5).abs() < 1e-20);
        let buckets = j.get("buckets").unwrap().as_array().unwrap();
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[1].get("n").unwrap().as_int(), Some(32));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x",null,true],"b":{"c":-3}}"#;
        let j = parse_json(src).unwrap();
        let back = parse_json(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn string_escapes() {
        let j = parse_json(r#""line\nquote\" tab\t uA""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nquote\" tab\t uA"));
        // serialize escapes back
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(parse_json(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn errors() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{\"a\" 1}").is_err());
        assert!(parse_json("nul").is_err());
        assert!(parse_json("1 2").is_err());
        assert!(parse_json("\"abc").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse_json("42").unwrap(), Json::Int(42));
        assert_eq!(parse_json("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse_json("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(parse_json("1e3").unwrap(), Json::Float(1000.0));
        // float that is integral still usable as int
        assert_eq!(parse_json("3.0").unwrap().as_int(), Some(3));
    }

    #[test]
    fn float_serialization_keeps_dot() {
        let s = Json::Float(2.0).to_string();
        assert_eq!(s, "2.0");
        assert_eq!(parse_json(&s).unwrap(), Json::Float(2.0));
    }
}
