//! Typed configuration consumed by the launcher (`dvi` CLI) and the
//! experiment harness. Values parse from the TOML subset in
//! [`super::toml`]; everything has sensible paper-faithful defaults so an
//! empty config reproduces the paper's protocol.

use super::toml::{parse_str, TomlError, Value};
use crate::linalg::ShardAxis;
use std::collections::BTreeMap;
use std::path::Path;

/// Which parallel CD arm the solver dispatches to when its effective
/// thread count is > 1 (`cd_threads() != 1`). Serial solves ignore this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CdMode {
    /// Block-synchronous sharded sweep (`solver/cd_par.rs`): deterministic
    /// per `(seed, threads)`, byte-identical to itself run-to-run. The
    /// default.
    Sync,
    /// Asynchronous "wild" sweep (`solver/cd_async.rs`): workers race
    /// atomic updates on a shared u with no block barrier. Faster on
    /// high-core machines; explicitly trades away run-to-run determinism
    /// (results remain KKT-valid at the same tol, with the same
    /// support/E-sets — see README §Solver).
    Async,
}

impl CdMode {
    /// Parse the CLI/TOML/JSON spelling.
    pub fn parse(s: &str) -> Option<CdMode> {
        match s {
            "sync" => Some(CdMode::Sync),
            "async" => Some(CdMode::Async),
            _ => None,
        }
    }

    /// The canonical spelling (round-trips through [`Self::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            CdMode::Sync => "sync",
            CdMode::Async => "async",
        }
    }
}

impl Default for CdMode {
    fn default() -> Self {
        CdMode::Sync
    }
}

/// Dual coordinate-descent solver parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SolverConfig {
    /// Stop when the maximal projected-gradient violation falls below tol.
    pub tol: f64,
    /// Hard cap on outer sweeps.
    pub max_outer: usize,
    /// Enable active-set shrinking.
    pub shrink: bool,
    /// Seed for the coordinate permutation.
    pub seed: u64,
    /// Worker threads for the sharded screening scan, θ-form Gram build,
    /// and full-problem KKT validation: 1 = serial (default — jobs already
    /// run on a worker pool), 0 = auto-detect, n = n threads (clamped to
    /// the row count and to 4× the hardware parallelism). The *scan*
    /// engines' decisions are byte-identical for every setting — but the
    /// CD solver also inherits this value when `solver_threads` is unset,
    /// and its iterates are NOT bitwise-equal across thread counts (they
    /// are KKT/decision-equivalent; see `solver_threads`). Pin
    /// `solver_threads = 1` alongside `threads > 1` to keep solver
    /// trajectories bit-for-bit serial.
    pub threads: usize,
    /// Worker threads for the block-synchronous parallel CD sweep
    /// ([`crate::solver`]): `None` inherits `threads` (the CLI's
    /// `--solver-threads` default), `Some(1)` forces the serial sweep,
    /// `Some(0)` auto-detects. Unlike the scan, the parallel sweep's
    /// iterates are NOT bitwise-equal across thread counts — they are
    /// deterministic per `(seed, threads)` and converge to the same
    /// optimum at `tol` (see README §Solver).
    pub solver_threads: Option<usize>,
    /// Parallel sweep flavor: [`CdMode::Sync`] (default, deterministic per
    /// `(seed, threads)`) or [`CdMode::Async`] (wild atomic updates,
    /// nondeterministic run-to-run). Ignored when `cd_threads() == 1`.
    pub cd_mode: CdMode,
    /// Which axis the n-dimensional hot paths (u = Zᵀθ reconstruction, w
    /// extraction, θ-form Gram build) shard over: `rows` (default, the
    /// historical row-major path), `cols` (feature-sharded over the lazy
    /// column mirror), or `auto` (per-instance pick from the cached
    /// shape/nnz balance). Results are byte-identical for every setting at
    /// every thread count — the axis only partitions work.
    pub shard_axis: ShardAxis,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            tol: 1e-6,
            max_outer: 2000,
            shrink: true,
            seed: 0x5EED,
            threads: 1,
            solver_threads: None,
            cd_mode: CdMode::Sync,
            shard_axis: ShardAxis::Rows,
        }
    }
}

impl SolverConfig {
    /// Thread count the CD solver actually uses: the explicit
    /// `solver_threads` override, else `threads` (0 = auto, crate
    /// convention).
    pub fn cd_threads(&self) -> usize {
        self.solver_threads.unwrap_or(self.threads)
    }
}

/// Regularization-path grid. The paper: 100 values of C in [1e-2, 10],
/// equally spaced in log scale.
#[derive(Clone, Debug, PartialEq)]
pub struct GridConfig {
    pub c_min: f64,
    pub c_max: f64,
    pub points: usize,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig { c_min: 1e-2, c_max: 10.0, points: 100 }
    }
}

impl GridConfig {
    /// Log-spaced grid values (ascending).
    pub fn values(&self) -> Vec<f64> {
        assert!(self.c_min > 0.0 && self.c_max > self.c_min && self.points >= 2);
        let (a, b) = (self.c_min.ln(), self.c_max.ln());
        (0..self.points)
            .map(|k| (a + (b - a) * k as f64 / (self.points - 1) as f64).exp())
            .collect()
    }
}

/// One path run: model × dataset × screening rule × grid.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// "svm" | "lad" | "wsvm"
    pub model: String,
    /// dataset registry name ("toy1".."toy3", "ijcnn1", ..., or a path to
    /// a libsvm file prefixed "file:")
    pub dataset: String,
    /// size scale for the simulated real sets (tests use ≪1)
    pub scale: f64,
    /// Screening-rule expression: an atom — "dvi" (w-form) | "dvi-theta"
    /// | "ssnsv" | "essnsv" | "none" — or a `+`-composition such as
    /// "dvi+essnsv" whose member regions are intersected per step.
    pub rule: String,
    /// Instance-matrix storage: "dense" | "csr" | "auto" (auto picks CSR
    /// at or below the density threshold when the dataset loads).
    /// Screening decisions and solver iterates are identical either way
    /// for the same matrix data. (One caveat: `Dataset::standardize` is
    /// storage-dependent by design — CSR standardization is scale-only to
    /// preserve sparsity, so a standardized CSR load differs from a
    /// standardized dense load of the same file.)
    pub storage: String,
    pub grid: GridConfig,
    pub solver: SolverConfig,
    /// Execute the screening scan through the AOT PJRT artifact instead of
    /// the native rust implementation.
    pub use_pjrt: bool,
    /// After each reduced solve, verify full-problem KKT over all l
    /// (safety validation; costs one extra scan).
    pub validate: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "svm".into(),
            dataset: "toy1".into(),
            scale: 1.0,
            rule: "dvi".into(),
            storage: "auto".into(),
            grid: GridConfig::default(),
            solver: SolverConfig::default(),
            use_pjrt: false,
            validate: false,
        }
    }
}

/// A named experiment (one of the paper's tables/figures) with its runs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub id: String,
    pub runs: Vec<RunConfig>,
    /// Output directory for CSV/fig artifacts.
    pub out_dir: String,
}

fn get_f64(m: &BTreeMap<String, Value>, k: &str, d: f64) -> Result<f64, TomlError> {
    match m.get(k) {
        None => Ok(d),
        Some(v) => v
            .as_float()
            .ok_or_else(|| TomlError { line: 0, msg: format!("`{k}` must be a number") }),
    }
}

fn get_usize(m: &BTreeMap<String, Value>, k: &str, d: usize) -> Result<usize, TomlError> {
    match m.get(k) {
        None => Ok(d),
        Some(v) => v
            .as_int()
            .filter(|&i| i >= 0)
            .map(|i| i as usize)
            .ok_or_else(|| TomlError { line: 0, msg: format!("`{k}` must be a non-negative int") }),
    }
}

fn get_opt_usize(m: &BTreeMap<String, Value>, k: &str) -> Result<Option<usize>, TomlError> {
    match m.get(k) {
        None => Ok(None),
        Some(v) => v
            .as_int()
            .filter(|&i| i >= 0)
            .map(|i| Some(i as usize))
            .ok_or_else(|| TomlError { line: 0, msg: format!("`{k}` must be a non-negative int") }),
    }
}

fn get_bool(m: &BTreeMap<String, Value>, k: &str, d: bool) -> Result<bool, TomlError> {
    match m.get(k) {
        None => Ok(d),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| TomlError { line: 0, msg: format!("`{k}` must be a bool") }),
    }
}

fn get_str(m: &BTreeMap<String, Value>, k: &str, d: &str) -> Result<String, TomlError> {
    match m.get(k) {
        None => Ok(d.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| TomlError { line: 0, msg: format!("`{k}` must be a string") }),
    }
}

impl RunConfig {
    /// Parse a run config from TOML text. Unknown keys are rejected to
    /// catch typos early.
    pub fn from_toml_str(src: &str) -> Result<RunConfig, TomlError> {
        let m = parse_str(src)?;
        const KNOWN: [&str; 18] = [
            "model",
            "dataset",
            "scale",
            "rule",
            "storage",
            "use_pjrt",
            "validate",
            "grid.c_min",
            "grid.c_max",
            "grid.points",
            "solver.tol",
            "solver.max_outer",
            "solver.shrink",
            "solver.seed",
            "solver.threads",
            "solver.solver_threads",
            "solver.cd_mode",
            "solver.shard_axis",
        ];
        for k in m.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(TomlError { line: 0, msg: format!("unknown config key `{k}`") });
            }
        }
        let d = RunConfig::default();
        let cfg = RunConfig {
            model: get_str(&m, "model", &d.model)?,
            dataset: get_str(&m, "dataset", &d.dataset)?,
            scale: get_f64(&m, "scale", d.scale)?,
            rule: get_str(&m, "rule", &d.rule)?,
            storage: get_str(&m, "storage", &d.storage)?,
            grid: GridConfig {
                c_min: get_f64(&m, "grid.c_min", d.grid.c_min)?,
                c_max: get_f64(&m, "grid.c_max", d.grid.c_max)?,
                points: get_usize(&m, "grid.points", d.grid.points)?,
            },
            solver: SolverConfig {
                tol: get_f64(&m, "solver.tol", d.solver.tol)?,
                max_outer: get_usize(&m, "solver.max_outer", d.solver.max_outer)?,
                shrink: get_bool(&m, "solver.shrink", d.solver.shrink)?,
                seed: get_usize(&m, "solver.seed", d.solver.seed as usize)? as u64,
                threads: get_usize(&m, "solver.threads", d.solver.threads)?,
                solver_threads: get_opt_usize(&m, "solver.solver_threads")?,
                cd_mode: {
                    let s = get_str(&m, "solver.cd_mode", d.solver.cd_mode.name())?;
                    CdMode::parse(&s).ok_or_else(|| TomlError {
                        line: 0,
                        msg: format!("`solver.cd_mode` must be \"sync\" or \"async\", got `{s}`"),
                    })?
                },
                shard_axis: {
                    let s = get_str(&m, "solver.shard_axis", d.solver.shard_axis.name())?;
                    ShardAxis::parse(&s).ok_or_else(|| TomlError {
                        line: 0,
                        msg: format!(
                            "`solver.shard_axis` must be \"rows\", \"cols\", or \"auto\", got `{s}`"
                        ),
                    })?
                },
            },
            use_pjrt: get_bool(&m, "use_pjrt", d.use_pjrt)?,
            validate: get_bool(&m, "validate", d.validate)?,
        };
        cfg.validate_semantics()?;
        Ok(cfg)
    }

    /// Load from a file.
    pub fn from_file(path: &Path) -> Result<RunConfig, TomlError> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| TomlError { line: 0, msg: format!("read {}: {e}", path.display()) })?;
        Self::from_toml_str(&src)
    }

    /// Semantic validation shared by every ingest surface (TOML configs
    /// and the screening service's JSON requests): model/rule/storage
    /// vocabulary, grid bounds, and the scale/tol ranges whose violation
    /// would OOM or wedge a worker rather than error cleanly.
    pub(crate) fn validate_semantics(&self) -> Result<(), TomlError> {
        let bad = |msg: String| Err(TomlError { line: 0, msg });
        if !["svm", "lad", "wsvm"].contains(&self.model.as_str()) {
            return bad(format!("unknown model `{}`", self.model));
        }
        // rule expressions (atoms and `+`-compositions) are validated by
        // the engine's parser so the accepted vocabulary — and the
        // actionable error enumerating it — cannot drift from the rules
        // that actually exist
        if let Err(e) = crate::screening::RuleExpr::parse(&self.rule) {
            return bad(e);
        }
        if crate::linalg::Storage::parse(&self.storage).is_none() {
            return bad(format!(
                "unknown storage `{}` (dense | csr | auto)",
                self.storage
            ));
        }
        if self.grid.c_min <= 0.0 || self.grid.c_max <= self.grid.c_min {
            return bad("grid must satisfy 0 < c_min < c_max".into());
        }
        if self.grid.points < 2 {
            return bad("grid.points must be ≥ 2".into());
        }
        if !(self.scale > 0.0 && self.scale <= 1.0) {
            return bad("scale must be in (0, 1]".into());
        }
        if !(self.solver.tol.is_finite() && self.solver.tol > 0.0) {
            // an infinite tol (e.g. a JSON/TOML `1e400` overflowing to
            // inf) would make any solve "converge" instantly
            return bad("solver.tol must be finite and positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_protocol() {
        let g = GridConfig::default();
        assert_eq!(g.points, 100);
        let v = g.values();
        assert_eq!(v.len(), 100);
        assert!((v[0] - 1e-2).abs() < 1e-12);
        assert!((v[99] - 10.0).abs() < 1e-9);
        // log-spacing: ratios constant
        let r0 = v[1] / v[0];
        let r50 = v[51] / v[50];
        assert!((r0 - r50).abs() < 1e-9);
    }

    #[test]
    fn parse_full_config() {
        let src = r#"
model = "lad"
dataset = "houses"
scale = 0.25
rule = "dvi-theta"
storage = "csr"
use_pjrt = true
validate = true

[grid]
c_min = 0.1
c_max = 5.0
points = 10

[solver]
tol = 1e-8
max_outer = 100
shrink = false
seed = 7
threads = 4
"#;
        let c = RunConfig::from_toml_str(src).unwrap();
        assert_eq!(c.model, "lad");
        assert_eq!(c.dataset, "houses");
        assert_eq!(c.storage, "csr");
        assert_eq!(c.grid.points, 10);
        assert_eq!(c.solver.seed, 7);
        assert_eq!(c.solver.threads, 4);
        assert!(c.use_pjrt && c.validate && !c.solver.shrink);
    }

    #[test]
    fn storage_defaults_auto_and_validates() {
        assert_eq!(RunConfig::from_toml_str("").unwrap().storage, "auto");
        assert_eq!(
            RunConfig::from_toml_str("storage = \"dense\"").unwrap().storage,
            "dense"
        );
        assert!(RunConfig::from_toml_str("storage = \"sparse\"").is_err());
    }

    #[test]
    fn threads_defaults_serial() {
        assert_eq!(RunConfig::from_toml_str("").unwrap().solver.threads, 1);
        // 0 = auto-detect is a legal setting
        assert_eq!(
            RunConfig::from_toml_str("[solver]\nthreads = 0").unwrap().solver.threads,
            0
        );
        assert!(RunConfig::from_toml_str("[solver]\nthreads = -2").is_err());
    }

    #[test]
    fn solver_threads_inherits_threads_unless_set() {
        let d = RunConfig::from_toml_str("").unwrap();
        assert_eq!(d.solver.solver_threads, None);
        assert_eq!(d.solver.cd_threads(), 1);
        let inherit = RunConfig::from_toml_str("[solver]\nthreads = 4").unwrap();
        assert_eq!(inherit.solver.cd_threads(), 4, "solver threads follow `threads`");
        let split =
            RunConfig::from_toml_str("[solver]\nthreads = 4\nsolver_threads = 1").unwrap();
        assert_eq!(split.solver.solver_threads, Some(1));
        assert_eq!(split.solver.cd_threads(), 1, "explicit override wins");
        assert_eq!(
            RunConfig::from_toml_str("[solver]\nsolver_threads = 0")
                .unwrap()
                .solver
                .cd_threads(),
            0,
            "0 = auto is legal"
        );
        assert!(RunConfig::from_toml_str("[solver]\nsolver_threads = -1").is_err());
        assert!(RunConfig::from_toml_str("[solver]\nsolver_threads = \"x\"").is_err());
    }

    #[test]
    fn cd_mode_parses_and_defaults_sync() {
        assert_eq!(RunConfig::from_toml_str("").unwrap().solver.cd_mode, CdMode::Sync);
        assert_eq!(
            RunConfig::from_toml_str("[solver]\ncd_mode = \"async\"")
                .unwrap()
                .solver
                .cd_mode,
            CdMode::Async
        );
        assert_eq!(
            RunConfig::from_toml_str("[solver]\ncd_mode = \"sync\"")
                .unwrap()
                .solver
                .cd_mode,
            CdMode::Sync
        );
        let err = RunConfig::from_toml_str("[solver]\ncd_mode = \"wild\"").unwrap_err();
        assert!(err.msg.contains("sync"), "{}", err.msg);
        assert!(RunConfig::from_toml_str("[solver]\ncd_mode = 3").is_err());
        // round-trip spellings
        for mode in [CdMode::Sync, CdMode::Async] {
            assert_eq!(CdMode::parse(mode.name()), Some(mode));
        }
    }

    #[test]
    fn shard_axis_parses_and_defaults_rows() {
        assert_eq!(
            RunConfig::from_toml_str("").unwrap().solver.shard_axis,
            ShardAxis::Rows
        );
        for (spelling, want) in
            [("rows", ShardAxis::Rows), ("cols", ShardAxis::Cols), ("auto", ShardAxis::Auto)]
        {
            let src = format!("[solver]\nshard_axis = \"{spelling}\"");
            assert_eq!(
                RunConfig::from_toml_str(&src).unwrap().solver.shard_axis,
                want,
                "{spelling}"
            );
        }
        let err = RunConfig::from_toml_str("[solver]\nshard_axis = \"columns\"").unwrap_err();
        assert!(err.msg.contains("rows"), "{}", err.msg);
        assert!(RunConfig::from_toml_str("[solver]\nshard_axis = 1").is_err());
    }

    #[test]
    fn empty_config_is_default() {
        let c = RunConfig::from_toml_str("").unwrap();
        assert_eq!(c, RunConfig::default());
    }

    #[test]
    fn rejects_unknown_key() {
        assert!(RunConfig::from_toml_str("modle = \"svm\"").is_err());
    }

    #[test]
    fn parses_composed_rule_expressions() {
        let cfg = RunConfig::from_toml_str("rule = \"dvi+essnsv\"").unwrap();
        assert_eq!(cfg.rule, "dvi+essnsv");
        // the rejection message must teach the valid vocabulary
        let err = RunConfig::from_toml_str("rule = \"dvi+bogus\"").unwrap_err();
        assert!(err.msg.contains("valid rules:"), "{}", err.msg);
        assert!(err.msg.contains("compose with `+`"), "{}", err.msg);
    }

    #[test]
    fn rejects_bad_semantics() {
        assert!(RunConfig::from_toml_str("model = \"nope\"").is_err());
        assert!(RunConfig::from_toml_str("rule = \"nope\"").is_err());
        assert!(RunConfig::from_toml_str("[grid]\nc_min = -1.0").is_err());
        assert!(RunConfig::from_toml_str("[grid]\npoints = 1").is_err());
        assert!(RunConfig::from_toml_str("scale = 2.0").is_err());
        assert!(RunConfig::from_toml_str("[solver]\ntol = 0.0").is_err());
    }

    #[test]
    fn rejects_type_mismatch() {
        assert!(RunConfig::from_toml_str("scale = \"big\"").is_err());
        assert!(RunConfig::from_toml_str("[solver]\nshrink = 1").is_err());
    }
}
