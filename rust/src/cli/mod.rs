//! Hand-rolled CLI (offline build — no clap).

use crate::config::RunConfig;
use crate::coordinator::ScreeningService;
use crate::data::{registry, Task};
use crate::experiments::{self, ExpOptions};
use std::collections::BTreeMap;
use std::path::PathBuf;

const USAGE: &str = "\
dvi — safe exact data reduction for SVM and LAD (DVI screening)

USAGE:
  dvi path [--dataset NAME] [--model svm|lad|wsvm] [--rule dvi|dvi-theta|ssnsv|essnsv|none]
           [--scale S] [--points N] [--c-min F] [--c-max F] [--tol F]
           [--threads N]  (scan/validate worker threads; 1 = serial, 0 = auto)
           [--storage dense|csr|auto]
           [--validate] [--pjrt] [--config FILE]
  dvi experiment --id fig1|tab1|fig2|tab2|fig3|tab3|all
           [--scale S] [--points N] [--tol F] [--out DIR] [--threads N] [--pjrt]
  dvi cv   [--dataset NAME] [--model svm|lad] [--folds K] [--scale S]
           [--points N] [--rule dvi|none]     cross-validated C selection
  dvi serve [--workers N] [--cache-mb MB]   line-JSON requests on stdin
  dvi gen-data --dataset NAME --out FILE [--scale S]
  dvi info                           runtime + artifact status
  dvi help

SERVE:
  The service reads one JSON request per line and answers one JSON line
  per request, in input order. Three request shapes: a path run (the
  default), {"kind": "screen", ...} for batch DVI screening of
  (c_prev, c) pairs against one resident instance, and {"batch": [...]}
  to fan a list of either across the pool and get one ordered response
  line back. Instances are cached in an LRU keyed by
  (dataset, model, storage, scale); --cache-mb sets its byte budget
  (default 256, 0 disables). See README.md § Screening service.

STORAGE:
  --storage picks the instance-matrix layout: `dense` (row-major buffer),
  `csr` (compressed sparse rows — libsvm files parse straight into CSR,
  no l*n buffer is ever allocated), or `auto` (default: CSR when the
  loaded density is <= 0.25, dense otherwise). Screening decisions and
  solver iterates are bit-identical across storages for the same matrix
  data; CSR multiplies scan and solve bandwidth by 1/density on sparse
  data. (Caveat: dataset standardization is scale-only on CSR to preserve
  sparsity, vs full z-score on dense.) Also available as the `storage`
  key in --config TOML (see examples/sparse_path.toml) and in serve
  requests.
";

/// Parse `--key value` / `--flag` style args into a map. Returns
/// (positional, flags).
fn parse_flags(args: &[String]) -> Result<(Vec<String>, BTreeMap<String, String>), String> {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            // boolean flags
            if matches!(key, "validate" | "pjrt" | "help") {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn get_f64(flags: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
    }
}

fn get_usize(flags: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
    }
}

/// Entry point; returns the process exit code.
pub fn dispatch(args: &[String]) -> i32 {
    match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "path" => cmd_path(rest),
        "cv" => cmd_cv(rest),
        "experiment" => cmd_experiment(rest),
        "serve" => cmd_serve(rest),
        "gen-data" => cmd_gen_data(rest),
        "info" => cmd_info(),
        other => Err(format!("unknown command `{other}`\n{USAGE}")),
    }
}

fn cmd_path(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let mut cfg = if let Some(file) = flags.get("config") {
        RunConfig::from_file(std::path::Path::new(file)).map_err(|e| e.to_string())?
    } else {
        RunConfig::default()
    };
    if let Some(v) = flags.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = flags.get("model") {
        cfg.model = v.clone();
    }
    if let Some(v) = flags.get("rule") {
        cfg.rule = v.clone();
    }
    if let Some(v) = flags.get("storage") {
        cfg.storage = v.clone();
    }
    cfg.scale = get_f64(&flags, "scale", cfg.scale)?;
    cfg.grid.points = get_usize(&flags, "points", cfg.grid.points)?;
    cfg.grid.c_min = get_f64(&flags, "c-min", cfg.grid.c_min)?;
    cfg.grid.c_max = get_f64(&flags, "c-max", cfg.grid.c_max)?;
    cfg.solver.tol = get_f64(&flags, "tol", cfg.solver.tol)?;
    cfg.solver.threads = get_usize(&flags, "threads", cfg.solver.threads)?;
    cfg.validate = cfg.validate || flags.contains_key("validate");
    cfg.use_pjrt = cfg.use_pjrt || flags.contains_key("pjrt");

    let spec = crate::coordinator::JobSpec::path(0, cfg);
    let outcome = crate::coordinator::run_job(&spec);
    match outcome.result {
        Err(e) => Err(e),
        Ok(reply) => {
            let s = reply.as_path().expect("path jobs return path summaries");
            println!(
                "dataset={} model={} rule={} l={} steps={}",
                s.dataset, s.model, s.rule, s.l, s.steps
            );
            println!(
                "mean rejection {:.2}%  init {:.3}s  screening {:.4}s  total {:.3}s  updates {}",
                100.0 * s.mean_rejection,
                s.init_secs,
                s.screen_secs,
                s.total_secs,
                s.total_updates
            );
            if let Some(v) = s.worst_violation {
                println!("worst full-KKT violation: {v:.3e}");
            }
            Ok(())
        }
    }
}

fn cmd_cv(args: &[String]) -> Result<(), String> {
    use crate::path::{cross_validate, PathConfig};
    use crate::problem::Model;
    use crate::screening::RuleKind;
    let (_, flags) = parse_flags(args)?;
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "toy1".into());
    let model = Model::parse(flags.get("model").map(String::as_str).unwrap_or("svm"))
        .ok_or("bad --model")?;
    let rule = RuleKind::parse(flags.get("rule").map(String::as_str).unwrap_or("dvi"))
        .ok_or("bad --rule")?;
    let folds = get_usize(&flags, "folds", 5)?;
    let scale = get_f64(&flags, "scale", 0.25)?;
    let points = get_usize(&flags, "points", 50)?;
    let ds = registry::resolve(&dataset, scale, model.expected_task())?;
    if ds.task != model.expected_task() {
        return Err(format!("dataset `{dataset}` does not match model"));
    }
    let cfg = PathConfig::log_grid(1e-2, 10.0, points);
    let r = cross_validate(model, &ds, &cfg, rule, folds, 0xCF);
    println!(
        "{}-fold CV on {} ({} rows): best C = {:.4} (score {:.4}); \
         {:.1}% mean rejection; {:.2}s",
        folds,
        ds.name,
        ds.len(),
        r.best_c(),
        r.mean_score[r.best_index],
        100.0 * r.mean_rejection,
        r.total_secs
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let id = flags.get("id").ok_or("--id required (fig1..fig3, tab1..tab3, all)")?;
    let mut opts = ExpOptions::default();
    opts.scale = get_f64(&flags, "scale", opts.scale)?;
    opts.points = get_usize(&flags, "points", opts.points)?;
    opts.tol = get_f64(&flags, "tol", opts.tol)?;
    opts.threads = get_usize(&flags, "threads", opts.threads)?;
    if let Some(dir) = flags.get("out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.use_pjrt = flags.contains_key("pjrt");
    let report = experiments::run(id, &opts)?;
    println!("{report}");
    println!("(CSV written to {})", opts.out_dir.display());
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let workers = get_usize(&flags, "workers", 2)?;
    // instance-cache budget in MiB; 0 disables residency entirely
    let cache_mb = get_usize(&flags, "cache-mb", 256)?;
    let mut svc = ScreeningService::with_cache(workers, cache_mb.saturating_mul(1024 * 1024));
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    svc.serve(stdin.lock(), stdout.lock()).map_err(|e| e.to_string())?;
    eprintln!("{}", svc.metrics().render());
    svc.shutdown();
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let name = flags.get("dataset").ok_or("--dataset required")?;
    let out = flags.get("out").ok_or("--out required")?;
    let scale = get_f64(&flags, "scale", 1.0)?;
    let ds = registry::resolve(name, scale, Task::Classification)?;
    crate::data::io::write_libsvm(&ds, std::path::Path::new(out))
        .map_err(|e| e.to_string())?;
    println!("wrote {} instances × {} features to {out}", ds.len(), ds.dim());
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("dvi-screen {}", crate::VERSION);
    let dir = crate::runtime::artifacts::default_dir();
    match crate::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} buckets, dtype {})", dir.display(), m.buckets.len(), m.dtype);
            for b in &m.buckets {
                println!("  {}x{} -> {}", b.l, b.n, b.file);
            }
            m.check_files().map_err(|e| e.to_string())?;
            println!("all artifact files present");
        }
        Err(e) => println!("artifacts: unavailable ({e}) — native screening only"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_mixed() {
        let args: Vec<String> = ["--scale", "0.5", "--validate", "--points", "10"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (pos, flags) = parse_flags(&args).unwrap();
        assert!(pos.is_empty());
        assert_eq!(flags["scale"], "0.5");
        assert_eq!(flags["validate"], "true");
        assert_eq!(flags["points"], "10");
    }

    #[test]
    fn parse_flags_missing_value() {
        let args = vec!["--scale".to_string()];
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn dispatch_help_and_unknown() {
        assert_eq!(dispatch(&["help".to_string()]), 0);
        assert_eq!(dispatch(&["bogus".to_string()]), 1);
        assert_eq!(dispatch(&[]), 0);
    }

    #[test]
    fn cmd_path_runs_tiny() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_sharded() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--threads", "3", "--validate",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_csr_storage() {
        let args: Vec<String> = [
            "path", "--dataset", "sparse:120:40", "--scale", "1.0", "--points", "4",
            "--tol", "1e-5", "--storage", "csr", "--threads", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        // bad storage value is a clean error, not a panic
        let bad: Vec<String> = ["path", "--dataset", "toy1", "--storage", "sparse"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&bad), 1);
    }

    #[test]
    fn cmd_gen_data_roundtrip() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_cli_gen_{}.svm", std::process::id()));
        let args: Vec<String> = [
            "gen-data",
            "--dataset",
            "toy2",
            "--scale",
            "0.02",
            "--out",
            p.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        assert!(p.exists());
        std::fs::remove_file(&p).ok();
    }
}
