//! Hand-rolled CLI (offline build — no clap).

use crate::config::RunConfig;
use crate::coordinator::ScreeningService;
use crate::data::{registry, Task};
use crate::experiments::{self, ExpOptions};
use std::collections::BTreeMap;
use std::path::PathBuf;

const USAGE: &str = "\
dvi — safe exact data reduction for SVM and LAD (DVI screening)

USAGE:
  dvi path [--dataset NAME] [--model svm|lad|wsvm] [--rule EXPR]
           (EXPR: dvi|dvi-theta|ssnsv|essnsv|none, composable with `+`,
            e.g. --rule \"dvi+essnsv\" intersects both rules' regions)
           [--scale S] [--points N] [--c-min F] [--c-max F] [--tol F]
           [--threads N]  (scan/validate worker threads; 1 = serial, 0 = auto)
           [--solver-threads N]  (CD sweep worker threads; defaults to --threads)
           [--cd-mode sync|async]  (parallel CD arm; default sync — see SOLVER)
           [--shard-axis rows|cols|auto]  (reconstruction axis — see SHARD-AXIS)
           [--storage dense|csr|auto]
           [--validate] [--pjrt] [--config FILE] [--trace-out FILE]
  dvi experiment --id fig1|tab1|fig2|tab2|fig3|tab3|ablation|all
           [--scale S] [--points N] [--tol F] [--out DIR] [--threads N] [--pjrt]
  dvi gauntlet [--rules e1,e2,...] [--datasets d1,d2] [--scale S] [--points N]
           [--tol F] [--threads N] [--out DIR] [--no-timings]
           races rule expressions (incl. `+`-compositions) against one
           shared solved C-path per dataset; writes BENCH_screening.json
           (--no-timings drops wall-clock fields so double runs are
            byte-identical — the CI smoke contract)
  dvi cv   [--dataset NAME] [--model svm|lad] [--folds K] [--scale S]
           [--points N] [--rule dvi|none]     cross-validated C selection
  dvi train [--dataset NAME] [--model svm|lad|wsvm] --c F [--scale S]
           [--tol F] [--threads N] [--solver-threads N] [--cd-mode sync|async]
           [--shard-axis rows|cols|auto] [--print-support]
           [--storage dense|csr|auto] [--out FILE] [--trace-out FILE]
  dvi predict --model FILE --dataset NAME [--scale S] [--storage ...]
           [--threads N] [--support-only] [--out FILE]
  dvi serve [--workers N] [--cache-mb MB] [--model-cache-mb MB]
           [--preload ds1,ds2 [--preload-scale S]]
           [--listen ADDR] [--socket PATH]  (network mode; default: stdin)
           [--model-dir DIR] [--max-inflight N] [--queue-cost N]
           [--trace-out FILE] [--metrics-listen HOST:PORT]
           line-JSON requests on stdin, TCP, or a unix socket
  dvi gen-data --dataset NAME --out FILE [--scale S]
  dvi info                           runtime + artifact status
  dvi help

SERVE:
  The service reads one JSON request per line and answers one JSON line
  per request, in input order. Request shapes: a path run (the default),
  {"kind": "screen", ...} for batch DVI screening of (c_prev, c) pairs
  against one resident instance, {"kind": "train", ...} /
  {"kind": "predict", ...} for the model-artifact loop,
  {"kind": "cache", ...} to list/evict resident cache entries,
  {"kind": "stats", ...} for one JSON snapshot of every metrics family,
  and {"batch": [...]} to fan a list of any of these across the pool and
  get one ordered response line back. Instances are cached in an LRU
  keyed by (dataset, model, storage, scale); --cache-mb sets its byte
  budget (default 256, 0 disables) and --model-cache-mb the
  trained-model cache's (default 64). --preload builds the named
  registry datasets into the instance cache before serving (at
  --preload-scale, default 1.0), logging per-dataset build time.

  --listen HOST:PORT and/or --socket PATH serve the same protocol to
  any number of concurrent network clients multiplexed onto one worker
  pool and one warm cache (port 0 picks a free port; the bound address
  is logged as `[serve] listening on ...`). Per connection, responses
  replay in input order after EOF, exactly like stdin mode; add
  "stream": true to a request (or batch line) to emit each response as
  its job completes instead — entries keep their ids, so streamed
  output re-sorted by id is byte-identical to the buffered session
  under "timings": false. --max-inflight caps one connection's
  in-flight requests (typed "code": "rejected" errors) and --queue-cost
  bounds the global queued cost estimate (typed "code": "overloaded");
  0 = unlimited. --model-dir DIR auto-loads every *.pallas-model
  artifact into the model cache at startup (corrupt files are skipped
  with a warning) and lets train requests carry "persist": true to
  write their artifact there — a restarted server answers predict by
  model_id with zero retrains. See README.md.

MODEL:
  `dvi train` solves one (dataset, model, C) problem and writes a
  versioned `.pallas-model` artifact (--out): magic + header + w +
  support set + the θ-form active rows + checksum; save -> load
  round-trips bit-identically and corrupt files are rejected. `dvi
  predict` scores a registry dataset (or `file:<path>` libsvm rows)
  against an artifact, one score per line, byte-identical for any
  --threads and --storage; --support-only scores via w re-derived from
  the stored active rows (bit-identical to the stored w). The serve
  kinds "train"/"predict" expose the same loop as a service: train
  responses carry a deterministic model_id that predict requests can
  address while the model is resident, or use "model_file" to load an
  artifact from disk.

SOLVER:
  The dual CD solver is sharded over a persistent pinned worker pool:
  long-lived solver threads are spawned once (growing to the largest
  shard count ever requested, then reused for every later solve and
  screening scan — one channel send per shard instead of one OS thread
  spawn), and shard k always runs on worker k, so per-worker caches and
  first-touch NUMA pages stay hot across the path. --solver-threads
  picks the shard count independently of --threads (which drives the
  scan, Gram build, and validation): 1 = the serial sweep, 0 = auto,
  default = whatever --threads is.

  --cd-mode picks the parallel arm (ignored when the effective solver
  thread count is 1):
    sync   block-synchronous sweeps, deterministic per (seed, threads)
           [default]
    async  wild/asynchronous sweeps — workers race atomic updates on a
           shared u with no block barrier, then a serial sweep confirms
           convergence; faster on many cores, nondeterministic run to run

  Determinism contract:
    mode   threads   guarantee
    sync   1         byte-identical to the serial solver, always
    sync   t fixed   byte-identical run-to-run for fixed (seed, t)
    sync   t varies  KKT-valid at --tol; same support set & decisions
    async  any       KKT-valid at --tol; same support set & decisions;
                     NOT byte-reproducible run-to-run
  Pin --solver-threads 1 (any mode) when diffing solver trajectories.
  Also available as `solver.solver_threads` / `solver.cd_mode` in
  --config TOML and as "solver_threads" / "cd_mode" in serve
  path/screen/train requests.

SHARD-AXIS:
  --shard-axis picks which axis the n-dimensional passes shard over on
  the solver pool — the exact u = Z^T theta reconstructions, trained-w
  accumulation, and the theta-form Gram build:
    rows  shard the l training rows (default; the pre-existing layout)
    cols  shard n contiguous feature columns via a lazily built
          column-major mirror (CSC for sparse storage), cached on the
          instance and charged to the instance-cache budget up front
    auto  per instance: cols when n >= 1024 and 4n >= l (wide data),
          rows otherwise
  Every axis replays the identical accumulation order per output
  component, so results are BIT-IDENTICAL across axes and thread
  counts — this is purely a performance knob (cols wins on wide data
  where n >> l). The resolved axis is emitted as the `shard_axis` attr
  on `sweep` and `screen_rows` trace spans. Also available as
  `solver.shard_axis` in --config TOML and as "shard_axis" in serve
  path/screen/train requests.

STORAGE:
  --storage picks the instance-matrix layout: `dense` (row-major buffer),
  `csr` (compressed sparse rows — libsvm files parse straight into CSR,
  no l*n buffer is ever allocated), or `auto` (default: CSR when the
  loaded density is <= 0.25, dense otherwise). Screening decisions and
  solver iterates are bit-identical across storages for the same matrix
  data; CSR multiplies scan and solve bandwidth by 1/density on sparse
  data. (Caveat: dataset standardization is scale-only on CSR to preserve
  sparsity, vs full z-score on dense.) Also available as the `storage`
  key in --config TOML (see examples/sparse_path.toml) and in serve
  requests.

OBSERVABILITY:
  --trace-out FILE (path, train, serve) enables span tracing and writes
  a Chrome trace-event JSON file on exit — open it in chrome://tracing
  or Perfetto. Spans cover the whole request lifecycle: connection ->
  request -> queue_wait -> job -> per-step screening and per-iteration
  CD sweeps. In serve network mode the trace also flushes on SIGTERM.
  Tracing writes only to the sidecar file: response bytes stay
  identical under \"timings\": false, and the disabled path costs one
  relaxed atomic load per span site.

  --metrics-listen HOST:PORT (serve) binds a scrape endpoint answering
  `GET /metrics` in Prometheus text format (port 0 picks a free port;
  the bound address is logged as `[serve] metrics listening on ...`).
  It renders every service metrics family plus solver-pool gauges
  (queue depth, per-worker busy seconds) and cumulative per-rule
  screening telemetry. See README.md OBSERVABILITY.
";

/// Parse `--key value` / `--flag` style args into a map. Returns
/// (positional, flags).
fn parse_flags(args: &[String]) -> Result<(Vec<String>, BTreeMap<String, String>), String> {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            // boolean flags
            if matches!(
                key,
                "validate" | "pjrt" | "help" | "support-only" | "print-support" | "no-timings"
            ) {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
                continue;
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| format!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    Ok((pos, flags))
}

fn get_f64(flags: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
    }
}

fn get_usize(flags: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize, String> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
    }
}

fn get_cd_mode(
    flags: &BTreeMap<String, String>,
    default: crate::config::CdMode,
) -> Result<crate::config::CdMode, String> {
    match flags.get("cd-mode") {
        None => Ok(default),
        Some(v) => crate::config::CdMode::parse(v)
            .ok_or_else(|| format!("--cd-mode must be sync|async, got `{v}`")),
    }
}

fn get_shard_axis(
    flags: &BTreeMap<String, String>,
    default: crate::config::ShardAxis,
) -> Result<crate::config::ShardAxis, String> {
    match flags.get("shard-axis") {
        None => Ok(default),
        Some(v) => crate::config::ShardAxis::parse(v)
            .ok_or_else(|| format!("--shard-axis must be rows|cols|auto, got `{v}`")),
    }
}

/// Arm span tracing if `--trace-out FILE` was passed. Call before the
/// command does any traced work so no spans are lost.
fn arm_trace(flags: &BTreeMap<String, String>) {
    if let Some(file) = flags.get("trace-out") {
        crate::obs::set_trace_out(PathBuf::from(file));
    }
}

/// Write the armed trace (if any) and tell the user where it went.
/// Trace-file write failures are reported but never fail the command —
/// the computed result already printed.
fn flush_trace() {
    match crate::obs::flush() {
        Ok(Some(path)) => eprintln!("[trace] wrote {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("[trace] write failed: {e}"),
    }
}

/// Entry point; returns the process exit code.
pub fn dispatch(args: &[String]) -> i32 {
    match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        "path" => cmd_path(rest),
        "cv" => cmd_cv(rest),
        "experiment" => cmd_experiment(rest),
        "gauntlet" => cmd_gauntlet(rest),
        "train" => cmd_train(rest),
        "predict" => cmd_predict(rest),
        "serve" => cmd_serve(rest),
        "gen-data" => cmd_gen_data(rest),
        "info" => cmd_info(),
        other => Err(format!("unknown command `{other}`\n{USAGE}")),
    }
}

fn cmd_path(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let mut cfg = if let Some(file) = flags.get("config") {
        RunConfig::from_file(std::path::Path::new(file)).map_err(|e| e.to_string())?
    } else {
        RunConfig::default()
    };
    if let Some(v) = flags.get("dataset") {
        cfg.dataset = v.clone();
    }
    if let Some(v) = flags.get("model") {
        cfg.model = v.clone();
    }
    if let Some(v) = flags.get("rule") {
        cfg.rule = v.clone();
    }
    if let Some(v) = flags.get("storage") {
        cfg.storage = v.clone();
    }
    cfg.scale = get_f64(&flags, "scale", cfg.scale)?;
    cfg.grid.points = get_usize(&flags, "points", cfg.grid.points)?;
    cfg.grid.c_min = get_f64(&flags, "c-min", cfg.grid.c_min)?;
    cfg.grid.c_max = get_f64(&flags, "c-max", cfg.grid.c_max)?;
    cfg.solver.tol = get_f64(&flags, "tol", cfg.solver.tol)?;
    cfg.solver.threads = get_usize(&flags, "threads", cfg.solver.threads)?;
    if flags.contains_key("solver-threads") {
        cfg.solver.solver_threads = Some(get_usize(&flags, "solver-threads", 0)?);
    }
    cfg.solver.cd_mode = get_cd_mode(&flags, cfg.solver.cd_mode)?;
    cfg.solver.shard_axis = get_shard_axis(&flags, cfg.solver.shard_axis)?;
    cfg.validate = cfg.validate || flags.contains_key("validate");
    cfg.use_pjrt = cfg.use_pjrt || flags.contains_key("pjrt");
    arm_trace(&flags);

    let spec = crate::coordinator::JobSpec::path(0, cfg);
    let outcome = crate::coordinator::run_job(&spec);
    flush_trace();
    match outcome.result {
        Err(e) => Err(e),
        Ok(reply) => {
            let s = reply.as_path().expect("path jobs return path summaries");
            println!(
                "dataset={} model={} rule={} l={} steps={}",
                s.dataset, s.model, s.rule, s.l, s.steps
            );
            println!(
                "mean rejection {:.2}%  init {:.3}s  screening {:.4}s  total {:.3}s  updates {}",
                100.0 * s.mean_rejection,
                s.init_secs,
                s.screen_secs,
                s.total_secs,
                s.total_updates
            );
            if let Some(v) = s.worst_violation {
                println!("worst full-KKT violation: {v:.3e}");
            }
            Ok(())
        }
    }
}

fn cmd_cv(args: &[String]) -> Result<(), String> {
    use crate::path::{cross_validate, PathConfig};
    use crate::problem::Model;
    use crate::screening::RuleKind;
    let (_, flags) = parse_flags(args)?;
    let dataset = flags.get("dataset").cloned().unwrap_or_else(|| "toy1".into());
    let model = Model::parse(flags.get("model").map(String::as_str).unwrap_or("svm"))
        .ok_or("bad --model")?;
    let rule_s = flags.get("rule").map(String::as_str).unwrap_or("dvi");
    let rule = RuleKind::parse(rule_s).ok_or_else(|| {
        format!(
            "bad --rule `{rule_s}` — valid rules: {}; cv races one rule \
             (no `+`-composition)",
            crate::screening::VALID_RULES
        )
    })?;
    let folds = get_usize(&flags, "folds", 5)?;
    let scale = get_f64(&flags, "scale", 0.25)?;
    let points = get_usize(&flags, "points", 50)?;
    let ds = registry::resolve(&dataset, scale, model.expected_task())?;
    if ds.task != model.expected_task() {
        return Err(format!("dataset `{dataset}` does not match model"));
    }
    let cfg = PathConfig::log_grid(1e-2, 10.0, points);
    let r = cross_validate(model, &ds, &cfg, rule, folds, 0xCF);
    println!(
        "{}-fold CV on {} ({} rows): best C = {:.4} (score {:.4}); \
         {:.1}% mean rejection; {:.2}s",
        folds,
        ds.name,
        ds.len(),
        r.best_c(),
        r.mean_score[r.best_index],
        100.0 * r.mean_rejection,
        r.total_secs
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let id = flags.get("id").ok_or("--id required (fig1..fig3, tab1..tab3, all)")?;
    let mut opts = ExpOptions::default();
    opts.scale = get_f64(&flags, "scale", opts.scale)?;
    opts.points = get_usize(&flags, "points", opts.points)?;
    opts.tol = get_f64(&flags, "tol", opts.tol)?;
    opts.threads = get_usize(&flags, "threads", opts.threads)?;
    if let Some(dir) = flags.get("out") {
        opts.out_dir = PathBuf::from(dir);
    }
    opts.use_pjrt = flags.contains_key("pjrt");
    let report = experiments::run(id, &opts)?;
    println!("{report}");
    println!("(CSV written to {})", opts.out_dir.display());
    Ok(())
}

fn cmd_gauntlet(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let mut opts = ExpOptions::default();
    opts.scale = get_f64(&flags, "scale", opts.scale)?;
    opts.points = get_usize(&flags, "points", opts.points)?;
    opts.tol = get_f64(&flags, "tol", opts.tol)?;
    opts.threads = get_usize(&flags, "threads", opts.threads)?;
    if let Some(dir) = flags.get("out") {
        opts.out_dir = PathBuf::from(dir);
    }
    let split = |v: &String| -> Vec<String> {
        v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
    };
    if let Some(v) = flags.get("rules") {
        opts.rules = split(v);
    }
    if let Some(v) = flags.get("datasets") {
        opts.bench_datasets = split(v);
    }
    opts.bench_timings = !flags.contains_key("no-timings");
    let report = experiments::run("gauntlet", &opts)?;
    println!("{report}");
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<(), String> {
    use crate::coordinator::{JobSpec, TrainSpec};
    use crate::linalg::Storage;
    use crate::problem::Model;
    let (_, flags) = parse_flags(args)?;
    let c = get_f64(&flags, "c", f64::NAN)?;
    if c.is_nan() {
        return Err("--c is required (the C to solve at)".into());
    }
    // same validity envelope as the service's train parser: a bad value
    // must not be baked into an artifact (and its id) with exit code 0
    if !(c.is_finite() && c > 0.0) {
        return Err(format!("--c must be finite and > 0, got {c}"));
    }
    let scale = get_f64(&flags, "scale", 1.0)?;
    if !(scale > 0.0 && scale <= 1.0) {
        return Err(format!("--scale must be in (0, 1], got {scale}"));
    }
    let tol = get_f64(&flags, "tol", 1e-6)?;
    if !(tol.is_finite() && tol > 0.0) {
        return Err(format!("--tol must be finite and > 0, got {tol}"));
    }
    let spec = TrainSpec {
        dataset: flags.get("dataset").cloned().unwrap_or_else(|| "toy1".into()),
        model: Model::parse(flags.get("model").map(String::as_str).unwrap_or("svm"))
            .ok_or("bad --model (svm | lad | wsvm)")?,
        scale,
        storage: Storage::parse(flags.get("storage").map(String::as_str).unwrap_or("auto"))
            .ok_or("bad --storage (dense | csr | auto)")?,
        c,
        solver: crate::config::SolverConfig {
            tol,
            threads: get_usize(&flags, "threads", 1)?,
            solver_threads: if flags.contains_key("solver-threads") {
                Some(get_usize(&flags, "solver-threads", 0)?)
            } else {
                None
            },
            cd_mode: get_cd_mode(&flags, crate::config::CdMode::default())?,
            shard_axis: get_shard_axis(&flags, crate::config::ShardAxis::default())?,
            ..Default::default()
        },
        save: flags.get("out").cloned(),
        persist_dir: None,
        report_support: flags.contains_key("print-support"),
    };
    arm_trace(&flags);
    let outcome = crate::coordinator::run_job(&JobSpec::train(0, spec));
    flush_trace();
    let reply = outcome.result?;
    let s = reply.as_train().expect("train jobs return train summaries");
    println!(
        "trained {} (model={} dataset={} C={} storage={})",
        s.model_id,
        s.model.wire_name(),
        s.dataset,
        s.c,
        s.storage.name()
    );
    println!(
        "l={} n={}  support={} ({:.1}%)  active={}  artifact {} bytes  solve {:.3}s",
        s.l,
        s.n,
        s.support,
        100.0 * s.support as f64 / s.l.max(1) as f64,
        s.active,
        s.artifact_bytes,
        s.solve_secs
    );
    if let Some(sup) = &s.support_indices {
        // one stable line the smoke script diffs between the serial and
        // parallel solvers (the sets must agree; see SOLVER help)
        let list: Vec<String> = sup.iter().map(|i| i.to_string()).collect();
        println!("support_indices={}", list.join(","));
    }
    match &s.saved {
        Some(p) => println!("saved {p}"),
        None => println!("(not persisted — pass --out FILE to write the artifact)"),
    }
    Ok(())
}

fn cmd_predict(args: &[String]) -> Result<(), String> {
    use crate::coordinator::{JobSpec, ModelRef, PredictInput, PredictSpec};
    use crate::linalg::Storage;
    let (_, flags) = parse_flags(args)?;
    let model_file = flags.get("model").cloned().ok_or("--model FILE is required")?;
    let dataset = flags
        .get("dataset")
        .cloned()
        .ok_or("--dataset NAME is required (registry name or file:<path>)")?;
    let scale = get_f64(&flags, "scale", 1.0)?;
    if !(scale > 0.0 && scale <= 1.0) {
        return Err(format!("--scale must be in (0, 1], got {scale}"));
    }
    let spec = PredictSpec {
        model: ModelRef::File(model_file),
        input: PredictInput::Dataset {
            name: dataset,
            scale,
            storage: Storage::parse(flags.get("storage").map(String::as_str).unwrap_or("auto"))
                .ok_or("bad --storage (dense | csr | auto)")?,
        },
        threads: get_usize(&flags, "threads", 1)?,
        support_only: flags.contains_key("support-only"),
    };
    let outcome = crate::coordinator::run_job(&JobSpec::predict(0, spec));
    let reply = outcome.result?;
    let s = reply.as_predict().expect("predict jobs return predict summaries");
    // one score per line, formatted exactly like the service's JSON
    // floats, so CLI output and service `scores` entries are directly
    // comparable byte for byte
    let mut text = String::with_capacity(s.scores.len() * 24);
    for &v in &s.scores {
        text.push_str(&crate::config::Json::Float(v).to_string());
        text.push('\n');
    }
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| format!("write {path}: {e}"))?;
            eprintln!("wrote {} scores to {path} (model {})", s.rows, s.model_id);
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    use crate::serve::{ModelRegistry, ServeOptions, Server};
    let (_, flags) = parse_flags(args)?;
    arm_trace(&flags);
    if flags.contains_key("trace-out") {
        // network mode blocks in wait() until the process is killed, so
        // a SIGTERM must flush the trace before exiting
        crate::obs::install_sigterm_flush();
    }
    let workers = get_usize(&flags, "workers", 2)?;
    // instance-cache budget in MiB; 0 disables residency entirely
    let cache_mb = get_usize(&flags, "cache-mb", 256)?;
    // trained-model cache budget in MiB
    let model_cache_mb = get_usize(&flags, "model-cache-mb", 64)?;
    let mut svc = ScreeningService::with_caches(
        workers,
        cache_mb.saturating_mul(1024 * 1024),
        model_cache_mb.saturating_mul(1024 * 1024),
    );
    if let Some(list) = flags.get("preload") {
        let scale = get_f64(&flags, "preload-scale", 1.0)?;
        let names: Vec<&str> = list.split(',').collect();
        for (name, result) in svc.preload(&names, scale) {
            match result {
                Ok((model, secs, bytes)) => eprintln!(
                    "[serve] preloaded {name} ({}, scale {scale}) in {secs:.3}s ({bytes} bytes)",
                    model.wire_name()
                ),
                Err(e) => eprintln!("[serve] preload {name} failed: {e}"),
            }
        }
    }

    let mut opts = ServeOptions::default();
    opts.max_inflight = get_usize(&flags, "max-inflight", 0)? as u64;
    opts.queue_cost = get_usize(&flags, "queue-cost", 0)? as u64;
    if let Some(dir) = flags.get("model-dir") {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("--model-dir {}: {e}", dir.display()))?;
        let pool = svc.pool_handle();
        let scan = ModelRegistry::new(&dir)
            .load_all(&pool.models, &pool.metrics)
            .map_err(|e| format!("--model-dir {}: {e}", dir.display()))?;
        for (id, file) in &scan.loaded {
            eprintln!("[serve] model-dir: loaded {id} from {}", file.display());
        }
        for (file, err) in &scan.skipped {
            eprintln!("[serve] model-dir: skipped {}: {err}", file.display());
        }
        opts.model_dir = Some(dir);
    }

    if let Some(addr) = flags.get("metrics-listen") {
        let registry = svc.pool_handle().metrics.clone();
        let render = std::sync::Arc::new(move || {
            crate::obs::expo::render_exposition(Some(&registry))
        });
        let bound = crate::obs::expo::serve_metrics(addr, render)
            .map_err(|e| format!("--metrics-listen {addr}: {e}"))?;
        eprintln!("[serve] metrics listening on {bound}");
    }

    let listen = flags.get("listen").cloned();
    let socket = flags.get("socket").cloned();
    if listen.is_some() || socket.is_some() {
        // network mode: accept loops own the process until killed
        let mut server = Server::new(svc.pool_handle(), opts);
        // graceful SIGTERM drain (unconditional — not just when tracing):
        // stop admitting (typed "draining" refusals), flush in-flight
        // jobs to the wire, then the watcher flushes any trace and exits
        let drain = server.drain_handle();
        crate::obs::set_sigterm_preflush(Box::new(move || {
            eprintln!("[serve] SIGTERM: draining in-flight requests");
            drain.begin();
            if drain.wait_idle(std::time::Duration::from_secs(30)) {
                eprintln!("[serve] drain complete");
            } else {
                eprintln!("[serve] drain timed out; exiting with jobs in flight");
            }
        }));
        crate::obs::install_sigterm_flush();
        if let Some(addr) = &listen {
            let bound = server.bind_tcp(addr).map_err(|e| format!("--listen {addr}: {e}"))?;
            eprintln!("[serve] listening on {bound}");
        }
        if let Some(path) = &socket {
            #[cfg(unix)]
            {
                let p = std::path::Path::new(path);
                server.bind_unix(p).map_err(|e| format!("--socket {path}: {e}"))?;
                eprintln!("[serve] listening on unix:{path}");
            }
            #[cfg(not(unix))]
            return Err(format!("--socket {path}: unix sockets are not available here"));
        }
        server.wait();
        flush_trace();
        return Ok(());
    }

    // stdin/stdout mode: admission caps apply here too (0 = unlimited),
    // and the session shares the same connection handler as the network
    // listeners, so byte behavior is identical
    if opts.max_inflight != 0 || opts.queue_cost != 0 || opts.model_dir.is_some() {
        svc.set_serve_options(opts);
    }
    let stdin = std::io::stdin();
    svc.serve(stdin.lock(), std::io::stdout()).map_err(|e| e.to_string())?;
    eprintln!("{}", svc.metrics().render());
    svc.shutdown();
    flush_trace();
    Ok(())
}

fn cmd_gen_data(args: &[String]) -> Result<(), String> {
    let (_, flags) = parse_flags(args)?;
    let name = flags.get("dataset").ok_or("--dataset required")?;
    let out = flags.get("out").ok_or("--out required")?;
    let scale = get_f64(&flags, "scale", 1.0)?;
    let ds = registry::resolve(name, scale, Task::Classification)?;
    crate::data::io::write_libsvm(&ds, std::path::Path::new(out))
        .map_err(|e| e.to_string())?;
    println!("wrote {} instances × {} features to {out}", ds.len(), ds.dim());
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("dvi-screen {}", crate::VERSION);
    let dir = crate::runtime::artifacts::default_dir();
    match crate::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} buckets, dtype {})", dir.display(), m.buckets.len(), m.dtype);
            for b in &m.buckets {
                println!("  {}x{} -> {}", b.l, b.n, b.file);
            }
            m.check_files().map_err(|e| e.to_string())?;
            println!("all artifact files present");
        }
        Err(e) => println!("artifacts: unavailable ({e}) — native screening only"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_mixed() {
        let args: Vec<String> = ["--scale", "0.5", "--validate", "--points", "10"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (pos, flags) = parse_flags(&args).unwrap();
        assert!(pos.is_empty());
        assert_eq!(flags["scale"], "0.5");
        assert_eq!(flags["validate"], "true");
        assert_eq!(flags["points"], "10");
    }

    #[test]
    fn parse_flags_missing_value() {
        let args = vec!["--scale".to_string()];
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn dispatch_help_and_unknown() {
        assert_eq!(dispatch(&["help".to_string()]), 0);
        assert_eq!(dispatch(&["bogus".to_string()]), 1);
        assert_eq!(dispatch(&[]), 0);
    }

    #[test]
    fn cmd_path_runs_tiny() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_sharded() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--threads", "3", "--validate",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_parallel_solver() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--solver-threads", "3", "--validate",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        // --threads alone now drives the solver too (inheritance)
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--threads", "2", "--solver-threads", "0",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_composed_rule_and_rejects_bad_expr() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--rule", "dvi+essnsv",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        let bad: Vec<String> = ["path", "--dataset", "toy1", "--rule", "dvi+bogus"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&bad), 1);
    }

    #[test]
    fn cmd_gauntlet_writes_bench_json() {
        let mut dir = std::env::temp_dir();
        dir.push(format!("dvi_cli_gauntlet_{}", std::process::id()));
        let args: Vec<String> = [
            "gauntlet", "--datasets", "toy1", "--rules", "dvi,dvi+essnsv", "--scale", "0.02",
            "--points", "3", "--tol", "1e-4", "--no-timings", "--out", dir.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        assert!(dir.join("BENCH_screening.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cmd_path_runs_async_solver_and_rejects_bad_mode() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "4", "--tol", "1e-5",
            "--solver-threads", "3", "--cd-mode", "async", "--validate",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        let bad: Vec<String> = ["path", "--dataset", "toy1", "--cd-mode", "wild"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&bad), 1);
    }

    #[test]
    fn cmd_path_and_train_accept_shard_axis() {
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "3", "--tol", "1e-5",
            "--threads", "2", "--shard-axis", "cols",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        let args: Vec<String> = [
            "train", "--dataset", "toy1", "--scale", "0.03", "--c", "0.5", "--tol", "1e-6",
            "--threads", "2", "--shard-axis", "auto",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        let bad: Vec<String> = ["path", "--dataset", "toy1", "--shard-axis", "columns"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&bad), 1);
    }

    #[test]
    fn cmd_train_accepts_cd_mode() {
        let args: Vec<String> = [
            "train", "--dataset", "toy1", "--scale", "0.03", "--c", "0.5", "--tol", "1e-6",
            "--solver-threads", "4", "--cd-mode", "async", "--print-support",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_train_prints_support_with_parallel_solver() {
        let args: Vec<String> = [
            "train", "--dataset", "toy1", "--scale", "0.03", "--c", "0.5", "--tol", "1e-6",
            "--solver-threads", "4", "--print-support",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
    }

    #[test]
    fn cmd_path_runs_csr_storage() {
        let args: Vec<String> = [
            "path", "--dataset", "sparse:120:40", "--scale", "1.0", "--points", "4",
            "--tol", "1e-5", "--storage", "csr", "--threads", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        // bad storage value is a clean error, not a panic
        let bad: Vec<String> = ["path", "--dataset", "toy1", "--storage", "sparse"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&bad), 1);
    }

    #[test]
    fn cmd_train_then_predict_roundtrip() {
        let pid = std::process::id();
        let mut model = std::env::temp_dir();
        model.push(format!("dvi_cli_train_{pid}.pallas-model"));
        let mut scores_a = std::env::temp_dir();
        scores_a.push(format!("dvi_cli_scores_a_{pid}.txt"));
        let mut scores_b = std::env::temp_dir();
        scores_b.push(format!("dvi_cli_scores_b_{pid}.txt"));

        let train: Vec<String> = [
            "train", "--dataset", "toy1", "--scale", "0.03", "--c", "0.5", "--tol", "1e-6",
            "--out", model.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&train), 0);
        assert!(model.exists());

        let predict = |support_only: bool, threads: &str, out: &std::path::Path| {
            let mut args: Vec<String> = [
                "predict", "--model", model.to_str().unwrap(), "--dataset", "toy1",
                "--scale", "0.03", "--threads", threads, "--out", out.to_str().unwrap(),
            ]
            .iter()
            .map(|s| s.to_string())
            .collect();
            if support_only {
                args.push("--support-only".into());
            }
            assert_eq!(dispatch(&args), 0);
        };
        predict(false, "1", &scores_a);
        predict(true, "3", &scores_b);
        let a = std::fs::read_to_string(&scores_a).unwrap();
        let b = std::fs::read_to_string(&scores_b).unwrap();
        assert_eq!(a, b, "support-only and threaded scoring are byte-identical");
        assert_eq!(a.lines().count(), 60, "one score per toy1 row at scale 0.03");
        assert!(a.lines().all(|l| l.parse::<f64>().is_ok()), "{a}");

        for p in [&model, &scores_a, &scores_b] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn cmd_train_and_predict_reject_bad_flags() {
        // train without --c
        let args: Vec<String> =
            ["train", "--dataset", "toy1"].iter().map(|s| s.to_string()).collect();
        assert_eq!(dispatch(&args), 1);
        // out-of-envelope values error instead of training junk
        for bad in [
            vec!["train", "--dataset", "toy1", "--c", "-1"],
            vec!["train", "--dataset", "toy1", "--c", "0.5", "--tol", "-1e-6"],
            vec!["train", "--dataset", "toy1", "--c", "0.5", "--tol", "0"],
            vec!["train", "--dataset", "toy1", "--c", "0.5", "--scale", "5.0"],
        ] {
            let args: Vec<String> = bad.iter().map(|s| s.to_string()).collect();
            assert_eq!(dispatch(&args), 1, "{bad:?}");
        }
        // predict without a model file
        let args: Vec<String> =
            ["predict", "--dataset", "toy1"].iter().map(|s| s.to_string()).collect();
        assert_eq!(dispatch(&args), 1);
        // predict against a missing artifact
        let args: Vec<String> = ["predict", "--model", "/no/such.pallas-model", "--dataset", "toy1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(dispatch(&args), 1);
    }

    #[test]
    fn cmd_path_trace_out_writes_chrome_json() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_cli_trace_{}.json", std::process::id()));
        let args: Vec<String> = [
            "path", "--dataset", "toy1", "--scale", "0.02", "--points", "3", "--tol", "1e-4",
            "--trace-out", p.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::config::parse_json(&text).unwrap();
        let events = j
            .as_object()
            .unwrap()
            .get("traceEvents")
            .unwrap()
            .as_array()
            .unwrap();
        assert!(!events.is_empty(), "a traced path run must export spans");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cmd_gen_data_roundtrip() {
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_cli_gen_{}.svm", std::process::id()));
        let args: Vec<String> = [
            "gen-data",
            "--dataset",
            "toy2",
            "--scale",
            "0.02",
            "--out",
            p.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(dispatch(&args), 0);
        assert!(p.exists());
        std::fs::remove_file(&p).ok();
    }
}
