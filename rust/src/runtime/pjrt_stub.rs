//! Offline stand-in for the PJRT screener (the real executor, behind the
//! `pjrt` cargo feature, lives in `pjrt.rs` and needs a vendored `xla`
//! crate). This stub keeps the exact public API so every caller — the
//! coordinator, the experiment harness, benches, and integration tests —
//! compiles unchanged:
//!
//! * constructors return [`PjrtError::Unavailable`], so callers take their
//!   existing "PJRT unavailable, use native" paths;
//! * the [`DviScanBackend`] impl falls back to the exact native f64 scan
//!   (counted in `fallbacks`), so a stub screener that does get wired into
//!   a path runner still produces correct decisions.

use super::artifacts::ArtifactManifest;
use crate::path::DviScanBackend;
use crate::problem::Instance;
use crate::screening::Decision;

/// Errors from the (stubbed) PJRT screening path.
#[derive(Debug)]
pub enum PjrtError {
    /// The crate was built without the `pjrt` feature.
    Unavailable(String),
}

impl std::fmt::Display for PjrtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PjrtError::Unavailable(m) => write!(f, "pjrt unavailable: {m}"),
        }
    }
}

impl std::error::Error for PjrtError {}

/// API-compatible stand-in for the XLA-backed screener.
pub struct PjrtScreener {
    /// Number of times the PJRT path failed and the native scan was used.
    pub fallbacks: u64,
    /// Number of successful PJRT scans (always 0 in the stub).
    pub scans: u64,
}

impl PjrtScreener {
    /// The stub cannot execute artifacts; construction always fails so
    /// callers fall back to the native backend.
    pub fn new(_manifest: ArtifactManifest) -> Result<PjrtScreener, PjrtError> {
        Err(PjrtError::Unavailable(
            "built without the `pjrt` cargo feature (offline default)".into(),
        ))
    }

    /// Load the manifest from the default artifact dir and build.
    pub fn from_default_dir() -> Result<PjrtScreener, PjrtError> {
        Err(PjrtError::Unavailable(
            "built without the `pjrt` cargo feature (offline default)".into(),
        ))
    }

    /// The PJRT scan proper; always errors in the stub.
    pub fn try_scan(
        &mut self,
        _inst: &Instance,
        _mid: f64,
        _rad: f64,
        _u: &[f64],
    ) -> Result<Vec<Decision>, PjrtError> {
        Err(PjrtError::Unavailable("no compiled artifact executor".into()))
    }

    /// Drop cached device buffers for an instance (no-op in the stub).
    pub fn evict(&mut self, _inst: &Instance) {}
}

impl DviScanBackend for PjrtScreener {
    fn scan(&mut self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
        // fail safe: the exact native scan
        self.fallbacks += 1;
        crate::screening::dvi::dvi_scan(inst, mid, rad, u)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_reports_missing_feature() {
        let err = PjrtScreener::from_default_dir().unwrap_err();
        assert!(err.to_string().contains("pjrt unavailable"), "{err}");
    }
}
