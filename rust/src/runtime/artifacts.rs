//! Artifact manifest: which AOT-compiled HLO executables exist and for
//! which (l_pad, n_pad) shape buckets.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` alongside one
//! `dvi_screen_{l}x{n}.hlo.txt` per bucket. HLO shapes are static, so the
//! runtime pads each dataset up to the smallest bucket that fits: padded
//! rows have zᵢ = 0, ‖zᵢ‖ = 0 and θᵢ = 0 so they influence nothing, and
//! their rule output is ignored.

use crate::config::json::{parse_json, Json};
use std::path::{Path, PathBuf};

/// One compiled shape bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeBucket {
    /// Padded instance count.
    pub l: usize,
    /// Padded feature dimension.
    pub n: usize,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
}

impl ShapeBucket {
    /// Whether a dataset of shape (l, n) fits in this bucket.
    pub fn fits(&self, l: usize, n: usize) -> bool {
        l <= self.l && n <= self.n
    }
    /// Padded element count (cost proxy for bucket selection).
    pub fn area(&self) -> usize {
        self.l * self.n
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub version: i64,
    pub dtype: String,
    /// Conservative guard band the kernel applies so f32 rounding can
    /// never produce an unsafe decision (see python/compile/model.py).
    pub guard_eps: f64,
    pub buckets: Vec<ShapeBucket>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

/// Manifest loading errors.
#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Json(crate::config::json::JsonError),
    Schema(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "io: {e}"),
            ManifestError::Json(e) => write!(f, "json: {e}"),
            ManifestError::Schema(m) => write!(f, "manifest: {m}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(e) => Some(e),
            ManifestError::Json(e) => Some(e),
            ManifestError::Schema(_) => None,
        }
    }
}

impl From<std::io::Error> for ManifestError {
    fn from(e: std::io::Error) -> Self {
        ManifestError::Io(e)
    }
}

impl From<crate::config::json::JsonError> for ManifestError {
    fn from(e: crate::config::json::JsonError) -> Self {
        ManifestError::Json(e)
    }
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest, ManifestError> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)?;
        Self::parse(&src, dir)
    }

    /// Parse manifest text (dir recorded for resolving bucket files).
    pub fn parse(src: &str, dir: &Path) -> Result<ArtifactManifest, ManifestError> {
        let j = parse_json(src)?;
        let schema = |m: &str| ManifestError::Schema(m.to_string());
        let version = j
            .get("version")
            .and_then(Json::as_int)
            .ok_or_else(|| schema("missing version"))?;
        if version != 1 {
            return Err(schema(&format!("unsupported manifest version {version}")));
        }
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| schema("missing dtype"))?
            .to_string();
        let guard_eps = j
            .get("guard_eps")
            .and_then(Json::as_float)
            .ok_or_else(|| schema("missing guard_eps"))?;
        let mut buckets = Vec::new();
        for b in j
            .get("buckets")
            .and_then(Json::as_array)
            .ok_or_else(|| schema("missing buckets"))?
        {
            let l = b.get("l").and_then(Json::as_int).ok_or_else(|| schema("bucket.l"))?;
            let n = b.get("n").and_then(Json::as_int).ok_or_else(|| schema("bucket.n"))?;
            let file = b
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| schema("bucket.file"))?
                .to_string();
            if l <= 0 || n <= 0 {
                return Err(schema("bucket dims must be positive"));
            }
            buckets.push(ShapeBucket { l: l as usize, n: n as usize, file });
        }
        if buckets.is_empty() {
            return Err(schema("no buckets"));
        }
        Ok(ArtifactManifest { version, dtype, guard_eps, buckets, dir: dir.to_path_buf() })
    }

    /// Smallest bucket (by padded area) that fits (l, n).
    pub fn pick(&self, l: usize, n: usize) -> Option<&ShapeBucket> {
        self.buckets
            .iter()
            .filter(|b| b.fits(l, n))
            .min_by_key(|b| b.area())
    }

    /// Absolute path of a bucket's HLO file.
    pub fn hlo_path(&self, bucket: &ShapeBucket) -> PathBuf {
        self.dir.join(&bucket.file)
    }

    /// Verify every bucket file exists on disk.
    pub fn check_files(&self) -> Result<(), ManifestError> {
        for b in &self.buckets {
            let p = self.hlo_path(b);
            if !p.is_file() {
                return Err(ManifestError::Schema(format!(
                    "missing artifact file {}",
                    p.display()
                )));
            }
        }
        Ok(())
    }
}

/// The default artifact directory: `$DVI_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "version": 1, "dtype": "f32", "guard_eps": 1e-5,
        "buckets": [
            {"l": 2048, "n": 16, "file": "a.hlo.txt"},
            {"l": 8192, "n": 16, "file": "b.hlo.txt"},
            {"l": 8192, "n": 64, "file": "c.hlo.txt"},
            {"l": 65536, "n": 64, "file": "d.hlo.txt"}
        ]
    }"#;

    #[test]
    fn parse_and_pick() {
        let m = ArtifactManifest::parse(DOC, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.buckets.len(), 4);
        // smallest fitting bucket wins
        assert_eq!(m.pick(1000, 10).unwrap().file, "a.hlo.txt");
        assert_eq!(m.pick(5000, 10).unwrap().file, "b.hlo.txt");
        assert_eq!(m.pick(5000, 54).unwrap().file, "c.hlo.txt");
        assert_eq!(m.pick(50_000, 22).unwrap().file, "d.hlo.txt");
        assert!(m.pick(100_000, 10).is_none());
        assert!(m.pick(10, 100).is_none());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = ArtifactManifest::parse(DOC, Path::new("/x/y")).unwrap();
        let b = m.pick(1, 1).unwrap();
        assert_eq!(m.hlo_path(b), PathBuf::from("/x/y/a.hlo.txt"));
    }

    #[test]
    fn schema_errors() {
        assert!(ArtifactManifest::parse("{}", Path::new(".")).is_err());
        assert!(ArtifactManifest::parse(
            r#"{"version": 2, "dtype": "f32", "guard_eps": 0.0, "buckets": []}"#,
            Path::new(".")
        )
        .is_err());
        assert!(ArtifactManifest::parse(
            r#"{"version": 1, "dtype": "f32", "guard_eps": 0.0, "buckets": []}"#,
            Path::new(".")
        )
        .is_err());
        assert!(ArtifactManifest::parse(
            r#"{"version": 1, "dtype": "f32", "guard_eps": 0.0,
                "buckets": [{"l": -1, "n": 2, "file": "x"}]}"#,
            Path::new(".")
        )
        .is_err());
    }

    #[test]
    fn check_files_reports_missing() {
        let m = ArtifactManifest::parse(DOC, Path::new("/nonexistent-dir-xyz")).unwrap();
        assert!(m.check_files().is_err());
    }
}
