//! PJRT execution of the AOT-compiled DVI screening scan.
//!
//! The artifact is the HLO text of the JAX/Pallas graph
//! `dvi_screen(z, u, ybar, znorm, mid, rad) -> codes` lowered once at
//! build time (see `python/compile/model.py` / `aot.py`). This module
//! compiles each shape bucket on the PJRT CPU client (once, cached),
//! keeps the per-dataset tensors (z, ȳ, ‖zᵢ‖) resident as device buffers,
//! and per path step uploads only u and the two scalars.
//!
//! Codes: 0 = Keep, 1 = AtLo (R), 2 = AtHi (L). The kernel applies a
//! conservative guard band (`manifest.guard_eps`) so that f32 rounding
//! can only ever *keep more* than the exact f64 rule — never screen an
//! instance the f64 rule would keep (parity-tested in
//! `rust/tests/integration_runtime.rs`).

use super::artifacts::{ArtifactManifest, ShapeBucket};
use crate::path::DviScanBackend;
use crate::problem::Instance;
use crate::screening::Decision;
use std::collections::HashMap;
use std::rc::Rc;

/// Errors from the PJRT screening path.
#[derive(Debug)]
pub enum PjrtError {
    Xla(String),
    NoBucket { l: usize, n: usize },
    BadOutput(String),
}

impl std::fmt::Display for PjrtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PjrtError::Xla(m) => write!(f, "xla: {m}"),
            PjrtError::NoBucket { l, n } => write!(f, "no shape bucket fits l={l}, n={n}"),
            PjrtError::BadOutput(m) => write!(f, "artifact output malformed: {m}"),
        }
    }
}

impl std::error::Error for PjrtError {}

impl From<xla::Error> for PjrtError {
    fn from(e: xla::Error) -> Self {
        PjrtError::Xla(e.to_string())
    }
}

struct CachedInstance {
    bucket: ShapeBucket,
    z: xla::PjRtBuffer,
    ybar: xla::PjRtBuffer,
    znorm: xla::PjRtBuffer,
}

/// PJRT-backed implementation of [`DviScanBackend`].
pub struct PjrtScreener {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    exes: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
    cache: HashMap<String, CachedInstance>,
    /// Number of times the PJRT path failed and the native scan was used.
    pub fallbacks: u64,
    /// Number of successful PJRT scans.
    pub scans: u64,
}

impl PjrtScreener {
    /// Create a screener over a loaded manifest. Compilation is lazy (per
    /// bucket, on first use).
    pub fn new(manifest: ArtifactManifest) -> Result<PjrtScreener, PjrtError> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtScreener {
            client,
            manifest,
            exes: HashMap::new(),
            cache: HashMap::new(),
            fallbacks: 0,
            scans: 0,
        })
    }

    /// Load the manifest from the default artifact dir and build.
    pub fn from_default_dir() -> Result<PjrtScreener, PjrtError> {
        let dir = super::artifacts::default_dir();
        let manifest = ArtifactManifest::load(&dir)
            .map_err(|e| PjrtError::Xla(format!("manifest: {e}")))?;
        PjrtScreener::new(manifest)
    }

    fn executable(
        &mut self,
        bucket: &ShapeBucket,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>, PjrtError> {
        if let Some(e) = self.exes.get(&bucket.file) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(bucket);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| PjrtError::BadOutput("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.exes.insert(bucket.file.clone(), exe.clone());
        Ok(exe)
    }

    fn cache_key(inst: &Instance) -> String {
        format!("{}:{}x{}", inst.name, inst.len(), inst.dim())
    }

    /// Upload the per-dataset tensors (padded to the bucket) once.
    fn ensure_instance(&mut self, inst: &Instance) -> Result<(), PjrtError> {
        let key = Self::cache_key(inst);
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let (l, n) = (inst.len(), inst.dim());
        let bucket = self
            .manifest
            .pick(l, n)
            .ok_or(PjrtError::NoBucket { l, n })?
            .clone();
        let (lp, np) = (bucket.l, bucket.n);

        // z padded (lp × np), row-major f32 — scatter the stored entries
        // so CSR instances never densify on the host side
        let mut zf = vec![0.0f32; lp * np];
        for i in 0..l {
            for (j, v) in inst.z.row(i).iter() {
                zf[i * np + j] = v as f32;
            }
        }
        let mut ybar = vec![0.0f32; lp];
        let mut znorm = vec![0.0f32; lp];
        for i in 0..l {
            ybar[i] = inst.ybar[i] as f32;
            znorm[i] = inst.z_norms_sq[i].sqrt() as f32;
        }
        let z = self.client.buffer_from_host_buffer(&zf, &[lp, np], None)?;
        let ybar = self.client.buffer_from_host_buffer(&ybar, &[lp], None)?;
        let znorm = self.client.buffer_from_host_buffer(&znorm, &[lp], None)?;
        self.cache.insert(key, CachedInstance { bucket, z, ybar, znorm });
        Ok(())
    }

    /// Drop cached device buffers for an instance (tests / memory).
    pub fn evict(&mut self, inst: &Instance) {
        self.cache.remove(&Self::cache_key(inst));
    }

    /// The PJRT scan proper; errors are surfaced (the trait impl falls
    /// back to the native scan).
    pub fn try_scan(
        &mut self,
        inst: &Instance,
        mid: f64,
        rad: f64,
        u: &[f64],
    ) -> Result<Vec<Decision>, PjrtError> {
        self.ensure_instance(inst)?;
        let key = Self::cache_key(inst);
        let bucket = self.cache[&key].bucket.clone();
        let exe = self.executable(&bucket)?;
        let cached = &self.cache[&key];

        // pad u to np
        let mut uf = vec![0.0f32; bucket.n];
        for (dst, &v) in uf.iter_mut().zip(u.iter()) {
            *dst = v as f32;
        }
        let u_buf = self.client.buffer_from_host_buffer(&uf, &[bucket.n], None)?;
        let mid_buf = self
            .client
            .buffer_from_host_buffer(&[mid as f32], &[], None)?;
        let rad_buf = self
            .client
            .buffer_from_host_buffer(&[rad as f32], &[], None)?;

        let outs = exe.execute_b(&[&cached.z, &u_buf, &cached.ybar, &cached.znorm, &mid_buf, &rad_buf])?;
        let lit = outs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| PjrtError::BadOutput("empty result".into()))?
            .to_literal_sync()?;
        let codes_lit = lit.to_tuple1()?;
        let codes = codes_lit.to_vec::<f32>()?;
        if codes.len() != bucket.l {
            return Err(PjrtError::BadOutput(format!(
                "expected {} codes, got {}",
                bucket.l,
                codes.len()
            )));
        }
        let decisions = codes[..inst.len()]
            .iter()
            .map(|&c| match c as i64 {
                1 => Decision::AtLo,
                2 => Decision::AtHi,
                _ => Decision::Keep,
            })
            .collect();
        self.scans += 1;
        Ok(decisions)
    }
}

impl DviScanBackend for PjrtScreener {
    fn scan(&mut self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
        match self.try_scan(inst, mid, rad, u) {
            Ok(d) => d,
            Err(e) => {
                // fail safe: fall back to the exact native scan
                self.fallbacks += 1;
                eprintln!("[pjrt] scan failed ({e}); falling back to native");
                crate::screening::dvi::dvi_scan(inst, mid, rad, u)
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    // PJRT integration tests live in rust/tests/integration_runtime.rs —
    // they need the artifacts built by `make artifacts`. Unit tests here
    // cover the pieces that do not require artifacts.
    use super::*;

    #[test]
    fn error_display() {
        let e = PjrtError::NoBucket { l: 10, n: 3 };
        assert!(e.to_string().contains("l=10"));
    }
}
