//! PJRT runtime: loads the AOT-compiled JAX/Pallas screening artifacts
//! (HLO text under `artifacts/`) and executes them from the rust hot path.
//! Python is build-time only — see `python/compile/aot.py`.
//!
//! The executor itself needs the `xla` crate, which is not part of the
//! offline build: every buildable configuration — including the `pjrt`
//! surface feature CI's feature matrix covers — uses the API-compatible
//! stub (sourced from `pjrt_stub.rs`) whose constructors report the
//! runtime as unavailable and whose [`crate::path::DviScanBackend`] impl
//! falls back to the exact native scan. The real executor source is kept
//! current in `pjrt.rs` but deliberately left out of the module tree (so
//! no feature combination can hit an unresolved-crate error); wire it in
//! behind a new feature when vendoring the `xla` crate (ROADMAP.md open
//! items). Manifest parsing ([`artifacts`]) is always available, so
//! `dvi info` and artifact validation work either way.

pub mod artifacts;

#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactManifest, ShapeBucket};
pub use pjrt::PjrtScreener;
