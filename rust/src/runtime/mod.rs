//! PJRT runtime: loads the AOT-compiled JAX/Pallas screening artifacts
//! (HLO text under `artifacts/`) and executes them from the rust hot path.
//! Python is build-time only — see `python/compile/aot.py`.
//!
//! The executor itself needs the `xla` crate, which is not part of the
//! offline build: the real implementation sits behind the `pjrt` cargo
//! feature, and the default build substitutes an API-compatible stub
//! (sourced from `pjrt_stub.rs`) whose constructors report the runtime as
//! unavailable and whose [`crate::path::DviScanBackend`] impl falls back
//! to the exact native scan. Manifest parsing ([`artifacts`]) is always
//! available, so `dvi info` and artifact validation work either way.

pub mod artifacts;

// The real executor references the `xla` crate, which must be vendored
// before the feature can build — fail with a named diagnostic instead of
// unresolved-crate errors deep inside pjrt.rs. Remove this guard when
// adding the vendored dependency (ROADMAP.md open items).
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires a vendored `xla` crate (not part of the \
     offline build); see ROADMAP.md open items"
);

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactManifest, ShapeBucket};
pub use pjrt::PjrtScreener;
