//! PJRT runtime: loads the AOT-compiled JAX/Pallas screening artifacts
//! (HLO text under `artifacts/`) and executes them from the rust hot path.
//! Python is build-time only — see `python/compile/aot.py`.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, ShapeBucket};
pub use pjrt::PjrtScreener;
