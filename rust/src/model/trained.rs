//! The trained-model artifact: everything prediction needs, nothing the
//! training pipeline keeps for itself.

use crate::linalg::{self, Cols, Rows, ShardAxis, Storage};
use crate::problem::{classify_kkt, Instance, KktClass, Model};

/// A solved classifier/regressor at one C, extracted from a dual optimum
/// θ*(C) on a built [`Instance`].
///
/// Two redundant representations are stored on purpose:
///
/// * `w` — the primal weights −C·Zᵀθ*, exactly as the training solve
///   produced them (the fast full-w scoring path);
/// * the *active set* — the rows with θᵢ ≠ 0 (for SVM: E ∪ L in the
///   paper's KKT partition) together with their θ values and their Z
///   rows, in the training instance's storage. These are the only rows
///   that contribute to w, so [`TrainedModel::reconstruct_w`] can replay
///   u = Σᵢ θᵢ·zᵢ from them alone — **bit-identical** to the stored `w`,
///   because both [`crate::linalg::RowMatrix::t_matvec`] and
///   [`crate::linalg::CsrMatrix::t_matvec`] already skip zero
///   coefficients and accumulate rows in ascending index order through
///   the same axpy kernels the replay uses.
///
/// `support` is the E-set (margin support vectors) from the KKT
/// classification at tolerance `tol` — the metadata the serving layer
/// reports as "support count vs l".
#[derive(Clone, Debug)]
pub struct TrainedModel {
    pub model: Model,
    /// Training dataset registry key (what a client would re-resolve).
    pub dataset: String,
    /// Resolved storage of the training instance (`Dense` or `Csr`,
    /// never `Auto`) — also the storage of `z_active`.
    pub storage: Storage,
    /// Dataset scale the instance was built with.
    pub scale: f64,
    /// The regularization parameter the model was solved at.
    pub c: f64,
    /// Solver tolerance of the training solve (also the KKT dead-band
    /// used to classify support vectors).
    pub tol: f64,
    /// Training rows l.
    pub l: usize,
    /// Intercept. Always 0.0 today — problem (3) is interceptless (LAD
    /// absorbs it by centering targets) — but the format reserves the
    /// slot so a biased variant is a payload change, not a version bump.
    pub bias: f64,
    /// Primal weights w*(C) = −C·Zᵀθ*(C), length n.
    pub w: Vec<f64>,
    /// E-set (margin support vector) indices, ascending.
    pub support: Vec<u32>,
    /// Indices with θᵢ ≠ 0, ascending — the rows w depends on.
    pub active: Vec<u32>,
    /// θ values at the active rows (same order as `active`).
    pub theta_active: Vec<f64>,
    /// The active rows of Z, selected in `active` order, in the training
    /// instance's storage.
    pub z_active: Rows,
}

impl TrainedModel {
    /// Extract the artifact from a solved dual point. `theta` must be the
    /// optimum of the boxed QP at `c` on `inst` (the caller's solver
    /// guarantees it to tolerance `tol`); `dataset`/`scale` are the
    /// registry key the instance was resolved from.
    pub fn from_solution(
        inst: &Instance,
        dataset: &str,
        scale: f64,
        c: f64,
        tol: f64,
        theta: &[f64],
    ) -> TrainedModel {
        Self::from_solution_axis(inst, dataset, scale, c, tol, theta, ShardAxis::Rows, 1)
    }

    /// [`TrainedModel::from_solution`] with the w-accumulation sharded
    /// over the requested axis — bit-identical extraction for every
    /// axis/thread count (the `cols` path replays the row-major
    /// accumulation per column slab; see [`crate::linalg::Cols`]).
    #[allow(clippy::too_many_arguments)]
    pub fn from_solution_axis(
        inst: &Instance,
        dataset: &str,
        scale: f64,
        c: f64,
        tol: f64,
        theta: &[f64],
        axis: ShardAxis,
        threads: usize,
    ) -> TrainedModel {
        assert_eq!(theta.len(), inst.len(), "theta length must equal l");
        assert!(c.is_finite() && c > 0.0, "C must be finite and positive");
        assert!(inst.len() <= u32::MAX as usize, "row count exceeds u32 index range");
        // u recomputed exactly from θ (not the solver's incrementally
        // maintained copy) so w is a pure function of θ — the same
        // convention the coordinator's screen jobs follow.
        let w = inst.w_from_theta_axis(c, theta, axis, threads);
        let support: Vec<u32> = classify_kkt(inst, &w, tol)
            .indices_of(KktClass::E)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        let active_usize: Vec<usize> =
            (0..theta.len()).filter(|&i| theta[i] != 0.0).collect();
        let theta_active: Vec<f64> = active_usize.iter().map(|&i| theta[i]).collect();
        let z_active = inst.z.select_rows(&active_usize);
        let storage = match &inst.z {
            Rows::Dense(_) => Storage::Dense,
            Rows::Sparse(_) => Storage::Csr,
        };
        TrainedModel {
            model: inst.model,
            dataset: dataset.to_string(),
            storage,
            scale,
            c,
            tol,
            l: inst.len(),
            bias: 0.0,
            w,
            support,
            active: active_usize.into_iter().map(|i| i as u32).collect(),
            theta_active,
            z_active,
        }
    }

    /// Feature dimension n.
    #[inline]
    pub fn n(&self) -> usize {
        self.w.len()
    }

    /// Deterministic model identity: the wire name plus an FNV-64 digest
    /// of the training key (dataset, resolved storage, scale/C/tol bit
    /// patterns, l) *continued over the solved weights' bit patterns*.
    /// The content digest matters: solver knobs the key cannot see (seed,
    /// iteration caps, shrinking, or an artifact produced by a different
    /// solver build) change w, so two models that would score differently
    /// can never share an id and silently replace each other in the
    /// model cache. The CD solver is deterministic, so identical train
    /// requests still reproduce the same id — which is what lets a
    /// `predict` request address a model trained by an earlier request,
    /// and keeps service responses byte-deterministic. Save → load
    /// preserves every bit, so the id survives the artifact round trip.
    pub fn id(&self) -> String {
        let key = format!(
            "{}|{}|{}|{:016x}|{:016x}|{:016x}|{:016x}|{}|{}|{}",
            self.model.name(),
            self.dataset,
            self.storage.name(),
            self.scale.to_bits(),
            self.c.to_bits(),
            self.tol.to_bits(),
            // bias is always 0.0 today, but the format reserves the slot:
            // the moment an artifact carries one, it must not hash like
            // its zero-bias sibling (same w, different scores)
            self.bias.to_bits(),
            self.l,
            // the payload lengths delimit the undelimited w‖θ byte
            // stream below: without them, w=[a,b],θ=[c] and w=[a],
            // θ=[b,c] would hash identically
            self.w.len(),
            self.theta_active.len()
        );
        let mut h = fnv64(key.as_bytes());
        for &v in &self.w {
            h = fnv64_continue(h, &v.to_bits().to_le_bytes());
        }
        for &v in &self.theta_active {
            h = fnv64_continue(h, &v.to_bits().to_le_bytes());
        }
        format!("{}-{:016x}", self.model.name(), h)
    }

    /// Fraction of training rows that are margin support vectors — the
    /// paper's test-phase selling point in one number.
    pub fn support_fraction(&self) -> f64 {
        if self.l == 0 {
            0.0
        } else {
            self.support.len() as f64 / self.l as f64
        }
    }

    /// Approximate resident bytes (the model cache charges entries with
    /// this, mirroring [`crate::problem::Instance::approx_bytes`]).
    pub fn approx_bytes(&self) -> usize {
        self.z_active.approx_bytes()
            + 8 * (self.w.len() + self.theta_active.len())
            + 4 * (self.support.len() + self.active.len())
            + self.dataset.len()
            + std::mem::size_of::<TrainedModel>()
    }

    /// Re-derive w from the stored active rows alone (the support-only
    /// path): u = Σₖ θₐ[k]·z_active[k] accumulated in ascending original
    /// row order, then w = −C·u. This replays exactly the nonzero terms
    /// `Instance::u_from_theta`'s t_matvec accumulated (both storages
    /// skip θᵢ = 0 rows), through the same axpy kernels, in the same
    /// order — so the result is bit-identical to the stored `w`.
    pub fn reconstruct_w(&self) -> Vec<f64> {
        let mut u = vec![0.0; self.n()];
        for (k, &t) in self.theta_active.iter().enumerate() {
            self.z_active.row(k).axpy_into(t, &mut u);
        }
        linalg::scale(-self.c, &mut u);
        u
    }

    /// [`TrainedModel::reconstruct_w`], feature-sharded when it pays: for
    /// wide models (n ≥ 1024, not strongly tall — the instance layer's
    /// auto heuristic applied to the active set) a transient column mirror
    /// of the stored active rows is built (O(active nnz)) and disjoint
    /// column slabs accumulate on the solver pool via
    /// [`Cols::accum_slab`], which replays the serial unconditional-axpy
    /// order exactly — the result is bit-identical to
    /// [`TrainedModel::reconstruct_w`] at every thread count. Narrow or
    /// tall models (and `threads <= 1`) keep the serial replay.
    pub fn reconstruct_w_threads(&self, threads: usize) -> Vec<f64> {
        let n = self.n();
        let rows = self.z_active.rows();
        let t = linalg::par::effective_threads(threads, n.max(1));
        if t <= 1 || n < 1024 || 4 * n < rows {
            return self.reconstruct_w();
        }
        let cols = Cols::from_rows(&self.z_active);
        let mut u = vec![0.0; n];
        let bounds = cols.balanced_bounds(t);
        linalg::par::run_sharded_mut(&mut u, 1, &bounds, |range, slab| {
            cols.accum_slab(&self.theta_active, range.start, range.end, slab);
        });
        linalg::scale(-self.c, &mut u);
        u
    }
}

/// FNV-1a 64-bit — the crate-local content digest (std-only; also the
/// checksum primitive of the on-disk format).
pub(crate) fn fnv64(bytes: &[u8]) -> u64 {
    fnv64_continue(0xcbf2_9ce4_8422_2325, bytes)
}

/// Continue an FNV-1a digest over more bytes (streaming form — feeding
/// buffers piecewise equals hashing their concatenation).
pub(crate) fn fnv64_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Test fixture shared by the format/predict unit tests: a small solved
/// SVM on the toy set in the requested storage.
#[cfg(test)]
pub(crate) fn trained_toy(storage: Storage) -> TrainedModel {
    use crate::config::SolverConfig;
    use crate::solver::CdSolver;
    let ds = crate::data::synth::toy_gaussian(11, 60, 1.0, 0.75).into_storage(storage);
    let inst = Instance::from_dataset(Model::Svm, &ds);
    let r = CdSolver::new(SolverConfig { tol: 1e-8, ..Default::default() })
        .solve(&inst, 0.5, inst.cold_start());
    TrainedModel::from_solution(&inst, "toy1", 0.06, 0.5, 1e-8, &r.theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extraction_shapes_and_metadata() {
        let m = trained_toy(Storage::Dense);
        assert_eq!(m.model, Model::Svm);
        assert_eq!(m.dataset, "toy1");
        assert_eq!(m.storage, Storage::Dense);
        assert_eq!(m.l, 120);
        assert_eq!(m.n(), 2);
        assert_eq!(m.active.len(), m.theta_active.len());
        assert_eq!(m.z_active.rows(), m.active.len());
        assert_eq!(m.z_active.cols(), m.n());
        // a solved SVM on a separable-ish toy has far fewer margin SVs
        // than rows, and every support index is in range and ascending
        assert!(!m.support.is_empty() && m.support.len() < m.l);
        assert!(m.support.windows(2).all(|w| w[0] < w[1]));
        assert!(m.active.windows(2).all(|w| w[0] < w[1]));
        assert!(m.support_fraction() > 0.0 && m.support_fraction() < 1.0);
        assert_eq!(m.bias, 0.0);
    }

    #[test]
    fn reconstructed_w_is_bit_identical() {
        for storage in [Storage::Dense, Storage::Csr] {
            let m = trained_toy(storage);
            let rebuilt = m.reconstruct_w();
            assert_eq!(rebuilt.len(), m.w.len());
            for (a, b) in rebuilt.iter().zip(&m.w) {
                assert_eq!(a.to_bits(), b.to_bits(), "storage {storage:?}");
            }
        }
    }

    #[test]
    fn threaded_reconstruction_bit_identical_on_wide_model() {
        use crate::config::SolverConfig;
        use crate::solver::CdSolver;
        // wide enough (n ≥ 1024) that reconstruct_w_threads takes the
        // column-sharded path instead of falling back to serial
        for storage in [Storage::Csr, Storage::Dense] {
            let ds = crate::data::synth::sparse_classes(31, 50, 1100, 0.02).into_storage(storage);
            let inst = Instance::from_dataset(Model::Svm, &ds);
            let r = CdSolver::new(SolverConfig { tol: 1e-6, ..Default::default() })
                .solve(&inst, 0.5, inst.cold_start());
            let m = TrainedModel::from_solution(&inst, "wide", 1.0, 0.5, 1e-6, &r.theta);
            let serial = m.reconstruct_w();
            for threads in [1usize, 2, 4, 7] {
                let par = m.reconstruct_w_threads(threads);
                assert_eq!(par, serial, "storage {storage:?} threads {threads}");
            }
            // extraction itself is axis/thread invariant too
            for threads in [2usize, 4] {
                for axis in [ShardAxis::Rows, ShardAxis::Cols, ShardAxis::Auto] {
                    let m2 = TrainedModel::from_solution_axis(
                        &inst, "wide", 1.0, 0.5, 1e-6, &r.theta, axis, threads,
                    );
                    assert_eq!(m2.w, m.w, "axis {} threads {threads}", axis.name());
                    assert_eq!(m2.id(), m.id(), "axis {} threads {threads}", axis.name());
                }
            }
        }
    }

    #[test]
    fn id_is_deterministic_and_parameter_sensitive() {
        let a = trained_toy(Storage::Dense);
        let b = trained_toy(Storage::Dense);
        assert_eq!(a.id(), b.id(), "same parameters, same id");
        assert!(a.id().starts_with("svm-"));
        let mut c = trained_toy(Storage::Dense);
        c.c = 0.7;
        assert_ne!(a.id(), c.id(), "C participates in the id");
        let d = trained_toy(Storage::Csr);
        assert_ne!(a.id(), d.id(), "resolved storage participates in the id");
    }

    #[test]
    fn id_folds_in_the_solved_weights() {
        let a = trained_toy(Storage::Dense);
        let mut b = trained_toy(Storage::Dense);
        assert_eq!(a.id(), b.id());
        // same training key, different weights (e.g. another solver
        // seed/build) must NOT collide
        b.w[0] += 1.0;
        assert_ne!(a.id(), b.id(), "content digest must separate the ids");
        let mut c = trained_toy(Storage::Dense);
        if let Some(t) = c.theta_active.first_mut() {
            *t *= 0.5;
        }
        assert_ne!(a.id(), c.id(), "θ payload participates too");
    }

    #[test]
    fn model_name_in_id_round_trips_through_parse() {
        let m = trained_toy(Storage::Dense);
        let prefix = m.id();
        let name = prefix.split('-').next().unwrap();
        assert_eq!(Model::parse(name), Some(m.model));
    }

    #[test]
    fn approx_bytes_positive_and_storage_sensitive() {
        let de = trained_toy(Storage::Dense);
        assert!(de.approx_bytes() > 8 * de.n());
        let sp = trained_toy(Storage::Csr);
        assert!(sp.approx_bytes() > 0);
    }

    #[test]
    #[should_panic(expected = "theta length")]
    fn rejects_wrong_theta_length() {
        let ds = crate::data::synth::toy_gaussian(12, 10, 1.0, 0.75);
        let inst = Instance::from_dataset(Model::Svm, &ds);
        TrainedModel::from_solution(&inst, "toy1", 1.0, 0.5, 1e-6, &[0.0; 3]);
    }

    #[test]
    fn fnv64_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }
}
