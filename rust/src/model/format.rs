//! The versioned `.pallas-model` binary on-disk format (std-only IO).
//!
//! Layout (all multi-byte fields little-endian):
//!
//! ```text
//! magic      [8]  b"PALLASMD"
//! version    u32  FORMAT_VERSION (readers reject anything else)
//! model      u8   0 = svm, 1 = lad, 2 = wsvm
//! storage    u8   0 = dense, 1 = csr      (layout of the z_active payload)
//! reserved   u16  0
//! l          u64  training rows
//! n          u64  feature dimension
//! n_support  u64  E-set size
//! n_active   u64  θ≠0 row count
//! c, scale, tol, bias                      4 × f64
//! dataset    u32 length + utf8 bytes       registry key
//! w          n × f64
//! support    n_support × u32               ascending
//! active     n_active × u32                ascending
//! theta      n_active × f64
//! z_active   dense: n_active·n × f64
//!            csr:   nnz u64, indptr (n_active+1) × u64,
//!                   indices nnz × u32, values nnz × f64
//! checksum   u64  FNV-1a 64 over every preceding byte
//! ```
//!
//! Versioning policy: any layout change bumps [`FORMAT_VERSION`]; loaders
//! reject unknown versions with [`ModelIoError::UnsupportedVersion`]
//! rather than guessing. Floats are stored as raw IEEE-754 bits, so
//! `save → load` round-trips every value bit-for-bit. The checksum is
//! verified before any field is parsed, so a bit-flipped artifact fails
//! with [`ModelIoError::ChecksumMismatch`] and a truncated one with
//! [`ModelIoError::Corrupt`] — never a panic or a silently wrong model.

use super::trained::{fnv64, TrainedModel};
use crate::linalg::{CsrMatrix, RowMatrix, Rows, Storage};
use crate::problem::Model;
use std::path::Path;

/// File magic: 8 bytes at offset 0.
pub const MAGIC: [u8; 8] = *b"PALLASMD";

/// Current (and only) format version.
pub const FORMAT_VERSION: u32 = 1;

/// Typed artifact IO errors.
#[derive(Debug)]
pub enum ModelIoError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// The file's version is not [`FORMAT_VERSION`].
    UnsupportedVersion(u32),
    /// Structural violation: truncation, counts that do not fit the
    /// remaining bytes, out-of-range indices, non-monotone indptr, …
    Corrupt(String),
    /// The trailing FNV-64 digest does not match the content.
    ChecksumMismatch { expected: u64, found: u64 },
}

impl std::fmt::Display for ModelIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelIoError::Io(e) => write!(f, "model io: {e}"),
            ModelIoError::BadMagic => write!(f, "not a .pallas-model file (bad magic)"),
            ModelIoError::UnsupportedVersion(v) => {
                write!(f, "unsupported .pallas-model version {v} (this build reads {FORMAT_VERSION})")
            }
            ModelIoError::Corrupt(msg) => write!(f, "corrupt .pallas-model: {msg}"),
            ModelIoError::ChecksumMismatch { expected, found } => write!(
                f,
                "corrupt .pallas-model: checksum mismatch (stored {expected:016x}, content {found:016x})"
            ),
        }
    }
}

impl std::error::Error for ModelIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ModelIoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ModelIoError {
    fn from(e: std::io::Error) -> Self {
        ModelIoError::Io(e)
    }
}

fn model_tag(m: Model) -> u8 {
    match m {
        Model::Svm => 0,
        Model::Lad => 1,
        Model::WeightedSvm => 2,
    }
}

fn model_from_tag(t: u8) -> Option<Model> {
    match t {
        0 => Some(Model::Svm),
        1 => Some(Model::Lad),
        2 => Some(Model::WeightedSvm),
        _ => None,
    }
}

/// Serialize a model to its on-disk bytes (checksum included).
pub fn encode(m: &TrainedModel) -> Vec<u8> {
    let mut out = Vec::with_capacity(128 + 8 * m.w.len() + 12 * m.active.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.push(model_tag(m.model));
    out.push(match m.z_active {
        Rows::Dense(_) => 0u8,
        Rows::Sparse(_) => 1u8,
    });
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(m.l as u64).to_le_bytes());
    out.extend_from_slice(&(m.n() as u64).to_le_bytes());
    out.extend_from_slice(&(m.support.len() as u64).to_le_bytes());
    out.extend_from_slice(&(m.active.len() as u64).to_le_bytes());
    for v in [m.c, m.scale, m.tol, m.bias] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&(m.dataset.len() as u32).to_le_bytes());
    out.extend_from_slice(m.dataset.as_bytes());
    for &v in &m.w {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for &i in &m.support {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for &i in &m.active {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for &v in &m.theta_active {
        out.extend_from_slice(&v.to_le_bytes());
    }
    match &m.z_active {
        Rows::Dense(d) => {
            for &v in d.flat() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Rows::Sparse(s) => {
            out.extend_from_slice(&(s.nnz() as u64).to_le_bytes());
            for &p in s.indptr() {
                out.extend_from_slice(&(p as u64).to_le_bytes());
            }
            for r in 0..s.rows() {
                let (idx, _) = s.row(r);
                for &j in idx {
                    out.extend_from_slice(&j.to_le_bytes());
                }
            }
            for r in 0..s.rows() {
                let (_, val) = s.row(r);
                for &v in val {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let sum = fnv64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Write a model artifact to `path`.
pub fn save(m: &TrainedModel, path: &Path) -> Result<(), ModelIoError> {
    std::fs::write(path, encode(m))?;
    Ok(())
}

/// Read a model artifact from `path`.
pub fn load(path: &Path) -> Result<TrainedModel, ModelIoError> {
    decode(&std::fs::read(path)?)
}

/// Bounds-checked little-endian reader over the artifact bytes.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, len: usize, what: &str) -> Result<&'a [u8], ModelIoError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| ModelIoError::Corrupt(format!("truncated in {what}")))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, ModelIoError> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, ModelIoError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32, ModelIoError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64, ModelIoError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64, ModelIoError> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// A `count` check that fails *before* any allocation: a corrupt
    /// count field must produce an error, not an OOM abort.
    fn usize_count(&mut self, what: &str) -> Result<usize, ModelIoError> {
        let v = self.u64(what)?;
        // every counted element occupies ≥ 4 bytes, so a legal count can
        // never exceed the file length
        if v > self.b.len() as u64 {
            return Err(ModelIoError::Corrupt(format!("{what} count {v} exceeds file size")));
        }
        Ok(v as usize)
    }

    fn f64_vec(&mut self, count: usize, what: &str) -> Result<Vec<f64>, ModelIoError> {
        let bytes = self.take(count.checked_mul(8).ok_or_else(|| {
            ModelIoError::Corrupt(format!("{what} size overflows"))
        })?, what)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32_vec(&mut self, count: usize, what: &str) -> Result<Vec<u32>, ModelIoError> {
        let bytes = self.take(count.checked_mul(4).ok_or_else(|| {
            ModelIoError::Corrupt(format!("{what} size overflows"))
        })?, what)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

fn check_indices(idx: &[u32], bound: usize, what: &str) -> Result<(), ModelIoError> {
    for w in idx.windows(2) {
        if w[0] >= w[1] {
            return Err(ModelIoError::Corrupt(format!("{what} indices not strictly ascending")));
        }
    }
    if let Some(&last) = idx.last() {
        if last as usize >= bound {
            return Err(ModelIoError::Corrupt(format!(
                "{what} index {last} out of range (bound {bound})"
            )));
        }
    }
    Ok(())
}

/// Parse artifact bytes. Magic, version, and checksum are verified before
/// any payload field; every structural invariant the predictor relies on
/// is re-validated so a corrupt file can never reach the scoring kernels.
pub fn decode(bytes: &[u8]) -> Result<TrainedModel, ModelIoError> {
    if bytes.len() < MAGIC.len() + 4 + 8 {
        return Err(ModelIoError::Corrupt("file shorter than header".into()));
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(ModelIoError::BadMagic);
    }
    let content = &bytes[..bytes.len() - 8];
    let stored = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let computed = fnv64(content);
    if stored != computed {
        return Err(ModelIoError::ChecksumMismatch { expected: stored, found: computed });
    }

    let mut r = Reader { b: content, pos: MAGIC.len() };
    let version = r.u32("version")?;
    if version != FORMAT_VERSION {
        return Err(ModelIoError::UnsupportedVersion(version));
    }
    let model = model_from_tag(r.u8("model tag")?)
        .ok_or_else(|| ModelIoError::Corrupt("unknown model tag".into()))?;
    let storage_tag = r.u8("storage tag")?;
    if storage_tag > 1 {
        return Err(ModelIoError::Corrupt("unknown storage tag".into()));
    }
    let _reserved = r.u16("reserved")?;
    // l is pure metadata (a model trained on 1M rows with a tiny active
    // set lives in a small file), so it is only bounded by the u32 index
    // range the support/active vectors use — unlike the payload counts
    // below, which must fit the remaining bytes.
    let l_raw = r.u64("l")?;
    if l_raw > u32::MAX as u64 {
        return Err(ModelIoError::Corrupt(format!("l {l_raw} exceeds the u32 index range")));
    }
    let l = l_raw as usize;
    let n = r.usize_count("n")?;
    let n_support = r.usize_count("support")?;
    let n_active = r.usize_count("active")?;
    if n_support > l || n_active > l {
        return Err(ModelIoError::Corrupt("support/active count exceeds l".into()));
    }
    let c = r.f64("c")?;
    let scale = r.f64("scale")?;
    let tol = r.f64("tol")?;
    let bias = r.f64("bias")?;
    if !(c.is_finite() && c > 0.0) {
        return Err(ModelIoError::Corrupt(format!("non-positive or non-finite C {c}")));
    }
    let ds_len = r.u32("dataset length")? as usize;
    let dataset = std::str::from_utf8(r.take(ds_len, "dataset")?)
        .map_err(|_| ModelIoError::Corrupt("dataset key is not utf-8".into()))?
        .to_string();
    let w = r.f64_vec(n, "w")?;
    let support = r.u32_vec(n_support, "support")?;
    check_indices(&support, l, "support")?;
    let active = r.u32_vec(n_active, "active")?;
    check_indices(&active, l, "active")?;
    let theta_active = r.f64_vec(n_active, "theta")?;
    let (z_active, storage) = if storage_tag == 0 {
        let flat = r.f64_vec(
            n_active.checked_mul(n).ok_or_else(|| {
                ModelIoError::Corrupt("dense payload size overflows".into())
            })?,
            "dense rows",
        )?;
        (Rows::Dense(RowMatrix::from_flat(n_active, n, flat)), Storage::Dense)
    } else {
        let nnz = r.usize_count("nnz")?;
        let indptr_raw = {
            let bytes = r.take(
                (n_active + 1).checked_mul(8).ok_or_else(|| {
                    ModelIoError::Corrupt("indptr size overflows".into())
                })?,
                "indptr",
            )?;
            bytes
                .chunks_exact(8)
                .map(|ch| u64::from_le_bytes(ch.try_into().unwrap()) as usize)
                .collect::<Vec<usize>>()
        };
        if indptr_raw.first() != Some(&0) || indptr_raw.last() != Some(&nnz) {
            return Err(ModelIoError::Corrupt("indptr must run 0..nnz".into()));
        }
        if indptr_raw.windows(2).any(|w| w[0] > w[1]) {
            return Err(ModelIoError::Corrupt("indptr not monotone".into()));
        }
        let indices = r.u32_vec(nnz, "csr indices")?;
        let values = r.f64_vec(nnz, "csr values")?;
        // rebuild through the validating constructor: per-row entries,
        // ascending column check included
        let mut entries: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n_active);
        for row in 0..n_active {
            let (a, b) = (indptr_raw[row], indptr_raw[row + 1]);
            let mut feats = Vec::with_capacity(b - a);
            let mut prev: Option<u32> = None;
            for k in a..b {
                let j = indices[k];
                if j as usize >= n {
                    return Err(ModelIoError::Corrupt(format!(
                        "csr column {j} out of range (n = {n})"
                    )));
                }
                if let Some(p) = prev {
                    if p >= j {
                        return Err(ModelIoError::Corrupt(
                            "csr columns not strictly ascending within a row".into(),
                        ));
                    }
                }
                prev = Some(j);
                feats.push((j as usize, values[k]));
            }
            entries.push(feats);
        }
        (Rows::Sparse(CsrMatrix::from_rows(entries, n)), Storage::Csr)
    };
    if r.pos != content.len() {
        return Err(ModelIoError::Corrupt(format!(
            "{} trailing bytes after payload",
            content.len() - r.pos
        )));
    }
    Ok(TrainedModel {
        model,
        dataset,
        storage,
        scale,
        c,
        tol,
        l,
        bias,
        w,
        support,
        active,
        theta_active,
        z_active,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::trained::trained_toy;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn encode_decode_round_trips_bit_identically() {
        for storage in [Storage::Dense, Storage::Csr] {
            let m = trained_toy(storage);
            let enc = encode(&m);
            let back = decode(&enc).expect("decode");
            assert_eq!(back.model, m.model);
            assert_eq!(back.dataset, m.dataset);
            assert_eq!(back.storage, m.storage);
            assert_eq!(back.l, m.l);
            assert_eq!(back.scale.to_bits(), m.scale.to_bits());
            assert_eq!(back.c.to_bits(), m.c.to_bits());
            assert_eq!(back.tol.to_bits(), m.tol.to_bits());
            assert_eq!(back.bias.to_bits(), m.bias.to_bits());
            assert_eq!(bits(&back.w), bits(&m.w));
            assert_eq!(back.support, m.support);
            assert_eq!(back.active, m.active);
            assert_eq!(bits(&back.theta_active), bits(&m.theta_active));
            assert_eq!(back.z_active, m.z_active);
            assert_eq!(back.id(), m.id());
            // a second encode of the decoded model is byte-identical
            assert_eq!(encode(&back), enc, "storage {storage:?}");
        }
    }

    #[test]
    fn save_load_file_round_trip() {
        let m = trained_toy(Storage::Dense);
        let mut p = std::env::temp_dir();
        p.push(format!("dvi_model_fmt_{}.pallas-model", std::process::id()));
        save(&m, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(bits(&back.w), bits(&m.w));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncation_at_every_prefix_is_rejected_cleanly() {
        let enc = encode(&trained_toy(Storage::Csr));
        // every strict prefix must error (not panic, not succeed);
        // step 7 keeps the loop fast while hitting unaligned cuts
        for cut in (0..enc.len()).step_by(7) {
            let e = decode(&enc[..cut]);
            assert!(e.is_err(), "prefix of {cut} bytes decoded");
        }
        let e = decode(&enc[..enc.len() - 1]);
        assert!(e.is_err());
    }

    #[test]
    fn bit_flips_are_rejected_by_the_checksum() {
        let enc = encode(&trained_toy(Storage::Dense));
        // flip one bit in a spread of positions across header and payload
        for pos in [8usize, 13, 40, enc.len() / 2, enc.len() - 9] {
            let mut bad = enc.clone();
            bad[pos] ^= 0x10;
            match decode(&bad) {
                Err(ModelIoError::ChecksumMismatch { .. }) | Err(ModelIoError::BadMagic) => {}
                other => panic!("flip at {pos}: expected checksum/magic error, got {other:?}"),
            }
        }
        // flipping the stored checksum itself also fails
        let mut bad = enc.clone();
        let last = bad.len() - 1;
        bad[last] ^= 1;
        assert!(matches!(decode(&bad), Err(ModelIoError::ChecksumMismatch { .. })));
    }

    #[test]
    fn bad_magic_and_version_are_typed_errors() {
        let enc = encode(&trained_toy(Storage::Dense));
        let mut bad = enc.clone();
        bad[0] = b'X';
        assert!(matches!(decode(&bad), Err(ModelIoError::BadMagic)));

        // bump the version and re-seal the checksum so ONLY the version
        // check can fire
        let mut v2 = enc.clone();
        v2[8] = 99;
        let body_len = v2.len() - 8;
        let sum = crate::model::trained::fnv64(&v2[..body_len]);
        v2[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&v2), Err(ModelIoError::UnsupportedVersion(99))));
    }

    #[test]
    fn corrupt_counts_fail_before_allocation() {
        let enc = encode(&trained_toy(Storage::Dense));
        // n lives at offset 8 (magic) + 4 (version) + 4 (tags/reserved)
        // + 8 (l) = 24; blow it up to a huge count and re-seal
        let mut bad = enc.clone();
        bad[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
        let body_len = bad.len() - 8;
        let sum = crate::model::trained::fnv64(&bad[..body_len]);
        bad[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode(&bad), Err(ModelIoError::Corrupt(_))));
    }

    #[test]
    fn error_messages_render() {
        let e = ModelIoError::ChecksumMismatch { expected: 1, found: 2 };
        assert!(e.to_string().contains("checksum"));
        assert!(ModelIoError::BadMagic.to_string().contains("magic"));
        assert!(ModelIoError::UnsupportedVersion(9).to_string().contains('9'));
    }
}
