//! Sharded batch prediction: score a [`Rows`] batch against a
//! [`TrainedModel`].
//!
//! Each row's score is `⟨xᵢ, w⟩` (+ bias when one ever becomes nonzero),
//! evaluated through [`crate::linalg::RowView::dot`] — the same
//! 8-accumulator kernels the screening scan uses, bit-identical across
//! dense and CSR storage of the same data. Batches are split into
//! contiguous shards balanced by stored-entry count
//! ([`Rows::balanced_shards`]) and evaluated on
//! [`par::run_sharded_ranges`] workers; every row's expression is
//! independent of the shard boundaries, so scores are byte-identical for
//! any thread count.

use super::trained::TrainedModel;
use crate::data::Task;
use crate::linalg::{par, Rows};

/// Prediction options.
#[derive(Clone, Copy, Debug)]
pub struct PredictOptions {
    /// Worker threads for the sharded scoring pass (crate convention:
    /// 1 = serial, 0 = auto-detect). Scores are identical either way.
    pub threads: usize,
    /// Score against w re-derived from the stored support/active rows in
    /// θ-form instead of the stored w. Bit-identical to full-w scoring
    /// (see [`TrainedModel::reconstruct_w`]) — this path exists so an
    /// artifact's θ-form payload is exercised end-to-end and a
    /// w-stripped artifact variant stays reachable.
    pub support_only: bool,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions { threads: 1, support_only: false }
    }
}

/// Score every row of `rows` against `model`. Errors (rather than
/// panics) on a feature-dimension mismatch — batches arrive over the
/// wire.
pub fn scores(
    model: &TrainedModel,
    rows: &Rows,
    opts: &PredictOptions,
) -> Result<Vec<f64>, String> {
    if rows.cols() != model.n() {
        return Err(format!(
            "rows have {} features but model `{}` expects {}",
            rows.cols(),
            model.id(),
            model.n()
        ));
    }
    let rebuilt;
    let w: &[f64] = if opts.support_only {
        rebuilt = model.reconstruct_w_threads(opts.threads);
        &rebuilt
    } else {
        &model.w
    };
    Ok(score_rows(rows, w, model.bias, opts.threads))
}

/// Score a flat row-major dense buffer (`width` columns) without
/// materializing a [`Rows`] — the zero-copy path for inline service
/// batches, which arrive already flattened. Bit-identical to wrapping
/// the same buffer in `Rows::Dense` and calling [`scores`]: each row's
/// expression is the same `linalg::dot` the dense `RowView` dispatches
/// to, and uniform sharding is exactly what `balanced_shards` produces
/// for dense storage.
pub fn scores_flat(
    model: &TrainedModel,
    flat: &[f64],
    width: usize,
    opts: &PredictOptions,
) -> Result<Vec<f64>, String> {
    if width == 0 || width != model.n() {
        return Err(format!(
            "rows have {width} features but model `{}` expects {}",
            model.id(),
            model.n()
        ));
    }
    if flat.len() % width != 0 {
        return Err(format!(
            "flat buffer of {} values is not a whole number of width-{width} rows",
            flat.len()
        ));
    }
    let rebuilt;
    let w: &[f64] = if opts.support_only {
        rebuilt = model.reconstruct_w_threads(opts.threads);
        &rebuilt
    } else {
        &model.w
    };
    let (bias, l) = (model.bias, flat.len() / width);
    let shards = par::run_sharded(l, opts.threads, |r| {
        let mut out = Vec::with_capacity(r.end - r.start);
        for i in r {
            let s = crate::linalg::dot(&flat[i * width..(i + 1) * width], w);
            out.push(if bias != 0.0 { s + bias } else { s });
        }
        out
    });
    let mut out = Vec::with_capacity(l);
    for mut s in shards {
        out.append(&mut s);
    }
    Ok(out)
}

/// The scoring kernel: out[i] = ⟨rowᵢ, w⟩ (+ bias when nonzero), sharded
/// over `threads` workers. Free function so benches and tests can drive
/// it against an arbitrary w.
pub fn score_rows(rows: &Rows, w: &[f64], bias: f64, threads: usize) -> Vec<f64> {
    let l = rows.rows();
    if l == 0 {
        return Vec::new();
    }
    let t = par::effective_threads(threads, l);
    let shards = par::run_sharded_ranges(rows.balanced_shards(t), |r| {
        let mut out = Vec::with_capacity(r.end - r.start);
        for i in r {
            let s = rows.row(i).dot(w);
            // adding a literal 0.0 would flip a −0.0 score's sign bit,
            // breaking bit-equality with direct ⟨x, w⟩ evaluation
            out.push(if bias != 0.0 { s + bias } else { s });
        }
        out
    });
    let mut out = Vec::with_capacity(l);
    for mut s in shards {
        out.append(&mut s);
    }
    out
}

/// ±1 labels from scores (classification models; `score > 0 → +1`).
pub fn labels(scores: &[f64]) -> Vec<i8> {
    scores.iter().map(|&s| if s > 0.0 { 1 } else { -1 }).collect()
}

/// Whether this model's scores carry class labels.
pub fn is_classifier(model: &TrainedModel) -> bool {
    model.model.expected_task() == Task::Classification
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Storage;
    use crate::model::trained::trained_toy;

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn batch(storage: Storage) -> Rows {
        let ds = crate::data::synth::toy_gaussian(23, 40, 1.0, 0.75);
        ds.x.into_storage(storage)
    }

    #[test]
    fn scores_match_direct_dot_bitwise_for_all_threads_and_storages() {
        let m = trained_toy(Storage::Dense);
        let dense = batch(Storage::Dense);
        let direct: Vec<f64> = (0..dense.rows()).map(|i| dense.row(i).dot(&m.w)).collect();
        for storage in [Storage::Dense, Storage::Csr] {
            let rows = batch(storage);
            for threads in [1usize, 2, 4, 0] {
                let got = scores(&m, &rows, &PredictOptions { threads, support_only: false })
                    .unwrap();
                assert_eq!(bits(&got), bits(&direct), "{storage:?} t={threads}");
            }
        }
    }

    #[test]
    fn support_only_scores_are_bit_identical() {
        for storage in [Storage::Dense, Storage::Csr] {
            let m = trained_toy(storage);
            let rows = batch(Storage::Dense);
            let full = scores(&m, &rows, &PredictOptions::default()).unwrap();
            let sup =
                scores(&m, &rows, &PredictOptions { threads: 2, support_only: true }).unwrap();
            assert_eq!(bits(&full), bits(&sup), "storage {storage:?}");
        }
    }

    #[test]
    fn scores_flat_matches_rows_path_bitwise() {
        let m = trained_toy(Storage::Dense);
        let rows = batch(Storage::Dense);
        let flat: Vec<f64> = (0..rows.rows()).flat_map(|i| rows.row(i).to_vec()).collect();
        for (threads, support_only) in [(1usize, false), (3, false), (2, true)] {
            let opts = PredictOptions { threads, support_only };
            let via_rows = scores(&m, &rows, &opts).unwrap();
            let via_flat = scores_flat(&m, &flat, m.n(), &opts).unwrap();
            assert_eq!(bits(&via_rows), bits(&via_flat), "t={threads} s={support_only}");
        }
        assert!(scores_flat(&m, &[], 0, &PredictOptions::default()).is_err());
        assert!(scores_flat(&m, &[1.0; 6], 3, &PredictOptions::default()).is_err());
        // ragged buffer is an error, not a silent truncation
        assert!(scores_flat(&m, &[1.0; 5], m.n(), &PredictOptions::default()).is_err());
    }

    #[test]
    fn dimension_mismatch_is_an_error() {
        let m = trained_toy(Storage::Dense);
        let wide = Rows::Dense(crate::linalg::RowMatrix::zeros(3, m.n() + 1));
        assert!(scores(&m, &wide, &PredictOptions::default()).is_err());
    }

    #[test]
    fn empty_batch_scores_empty() {
        let m = trained_toy(Storage::Dense);
        let empty = Rows::Dense(crate::linalg::RowMatrix::zeros(0, m.n()));
        assert!(scores(&m, &empty, &PredictOptions::default()).unwrap().is_empty());
    }

    #[test]
    fn labels_and_classifier_flag() {
        assert_eq!(labels(&[0.5, -0.1, 0.0]), vec![1, -1, -1]);
        let m = trained_toy(Storage::Dense);
        assert!(is_classifier(&m));
        // separable toy: the trained model should classify its own
        // training distribution well above chance
        let ds = crate::data::synth::toy_gaussian(23, 40, 1.0, 0.75);
        let s = scores(&m, &ds.x, &PredictOptions::default()).unwrap();
        let correct = labels(&s)
            .iter()
            .zip(&ds.y)
            .filter(|(&p, &y)| p as f64 * y > 0.0)
            .count();
        assert!(correct * 2 > ds.len(), "accuracy {}/{}", correct, ds.len());
    }

    #[test]
    fn bias_zero_preserves_negative_zero_scores() {
        let m = {
            let mut m = trained_toy(Storage::Dense);
            m.w = vec![0.0, -0.0];
            m
        };
        let rows = Rows::Dense(crate::linalg::RowMatrix::from_flat(1, 2, vec![1.0, 1.0]));
        let s = scores(&m, &rows, &PredictOptions::default()).unwrap();
        let direct = rows.row(0).dot(&m.w);
        assert_eq!(s[0].to_bits(), direct.to_bits());
    }
}
