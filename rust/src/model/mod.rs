//! Model artifact subsystem: persist solved classifiers, serve batch
//! prediction.
//!
//! DVI screening's selling point (PAPER.md §1) is that the final
//! classifier depends only on a small set of instances — yet until this
//! layer existed, a solved path threw its `(w, θ)` away and nothing in
//! the system could answer "classify these rows" without re-solving.
//! Ogawa et al. (*Safe Sample Screening for Support Vector Machines*,
//! PAPERS.md) make the same observation from the test-phase side: a
//! screened SVM is cheap to *serve* precisely because it is characterized
//! by a small support set. This module closes the loop
//! train → screen → solve → **persist → predict**:
//!
//! * [`TrainedModel`] ([`trained`]) — the solved classifier at one C:
//!   model kind, `w` (= −C·Zᵀθ*), the support-vector index set from the
//!   KKT classification, the *active* rows (θᵢ ≠ 0) in θ-form, and the
//!   training metadata (dataset key, C, storage, solver tol, support
//!   count vs l).
//! * [`format`] — the versioned `.pallas-model` binary on-disk format:
//!   magic + version + header + little-endian payload + FNV-64 checksum,
//!   std-only IO. `save → load` round-trips every float bit-for-bit;
//!   truncated or bit-flipped artifacts are rejected with typed
//!   [`ModelIoError`]s, never mis-parsed.
//! * [`predict`] — the sharded batch prediction engine: scores a
//!   [`crate::linalg::Rows`] batch (dense or CSR) against a model using
//!   the 8-accumulator dot kernels on
//!   [`crate::linalg::par::run_sharded_ranges`] workers. Scores are
//!   bit-identical for every thread count and storage. The optional
//!   support-only path re-derives w from just the stored active rows in
//!   θ-form — bit-identical to the stored w by the same
//!   accumulation-order argument the CSR kernels rely on.
//!
//! The coordinator layers a `ModelCache` (LRU by bytes, a sibling of the
//! instance cache), `"kind": "train"` / `"kind": "predict"` service
//! requests, and the `dvi train` / `dvi predict` CLI verbs on top of
//! this module.

pub mod format;
pub mod predict;
pub mod trained;

pub use format::{load, save, ModelIoError, FORMAT_VERSION, MAGIC};
pub use predict::{labels, scores, scores_flat, PredictOptions};
pub use trained::TrainedModel;
