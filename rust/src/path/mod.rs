//! Pathwise training: the paper's experimental protocol.
//!
//! Solve the dual at a grid 0 < C₁ < … < C_K (default: 100 points,
//! log-spaced in [1e-2, 10]); between consecutive points apply a screening
//! rule, snap the screened coordinates to their bound, and run the solver
//! only over the survivors (the Lemma-4 reduced problem, realized by
//! freezing coordinates inside [`crate::solver::CdSolver::solve_free`]).

pub mod runner;
pub mod select;

pub use runner::{PathConfig, PathOutput, PathRunner, StepRecord};
pub use select::{cross_validate, CvResult};

use crate::problem::Instance;
use crate::screening::Decision;

/// Pluggable backend for the DVI screening scan — the hot O(l·n) pass.
/// The native implementation lives in [`crate::screening::dvi`]; the PJRT
/// runtime provides an AOT-compiled JAX/Pallas implementation
/// ([`crate::runtime::PjrtScreener`]).
pub trait DviScanBackend {
    /// Evaluate the DVI decision for every instance.
    /// `mid` = (C_{k+1}+C_k)/2, `rad` = (C_{k+1}−C_k)/2, `u` = Zᵀθ*(C_k).
    fn scan(&mut self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision>;

    /// Identifier for reports.
    fn name(&self) -> &'static str;
}

/// Native rust backend (default).
pub struct NativeScan;

impl DviScanBackend for NativeScan {
    fn scan(&mut self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
        crate::screening::dvi::dvi_scan(inst, mid, rad, u)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Sharded multi-threaded backend: the l rows are split into contiguous
/// shards evaluated on `std::thread::scope` workers
/// ([`crate::screening::dvi::dvi_scan_par`]); the per-shard decision
/// vectors are merged in shard order, so the result is byte-identical to
/// [`NativeScan`] for any thread count.
pub struct ParScan {
    threads: usize,
}

impl ParScan {
    /// `threads == 0` auto-detects (`std::thread::available_parallelism`);
    /// `threads == 1` degenerates to the serial scan.
    pub fn new(threads: usize) -> ParScan {
        ParScan { threads }
    }

    /// Configured worker count (0 = auto).
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl DviScanBackend for ParScan {
    fn scan(&mut self, inst: &Instance, mid: f64, rad: f64, u: &[f64]) -> Vec<Decision> {
        crate::screening::dvi::dvi_scan_par(inst, mid, rad, u, self.threads)
    }

    fn name(&self) -> &'static str {
        "par"
    }
}
