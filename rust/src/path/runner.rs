//! The regularization-path runner.

use super::DviScanBackend;
use crate::config::{GridConfig, SolverConfig};
use crate::data::Dataset;
use crate::problem::{Instance, Model};
use crate::screening::{
    DviWRule, RuleExpr, RuleKind, ScreenReport, ScreeningRule, StepContext,
};
use crate::solver::CdSolver;
use std::time::Instant;

/// Path configuration: the C-grid plus solver settings.
#[derive(Clone, Debug)]
pub struct PathConfig {
    pub grid: Vec<f64>,
    pub solver: SolverConfig,
    /// After each reduced solve, recompute the *full-problem* KKT
    /// violation — the safety check (costs one extra O(l·n) scan).
    pub validate: bool,
    /// Warm-start each grid point from the previous solution. `true` is
    /// the strong modern baseline; `false` reproduces the paper's
    /// "Solver" arm (each C solved independently). Only honored for the
    /// `none` rule — every screening rule needs the previous solution
    /// anyway.
    pub warm_start: bool,
}

impl PathConfig {
    /// The paper's protocol: `points` log-spaced values in [c_min, c_max].
    pub fn log_grid(c_min: f64, c_max: f64, points: usize) -> PathConfig {
        PathConfig {
            grid: GridConfig { c_min, c_max, points }.values(),
            solver: SolverConfig::default(),
            validate: false,
            warm_start: true,
        }
    }

    /// Disable warm starts for the no-screening arm (the paper's
    /// baseline protocol).
    pub fn with_cold_baseline(mut self) -> Self {
        self.warm_start = false;
        self
    }

    pub fn with_solver(mut self, solver: SolverConfig) -> Self {
        self.solver = solver;
        self
    }

    pub fn with_validation(mut self, validate: bool) -> Self {
        self.validate = validate;
        self
    }
}

/// Measurements for one path point.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub c: f64,
    /// Instances fixed to the lower bound (paper's R̃ set).
    pub n_lo: usize,
    /// Instances fixed to the upper bound (paper's L̃ set).
    pub n_hi: usize,
    /// Coordinates entering the reduced solve.
    pub free: usize,
    pub screen_secs: f64,
    pub solve_secs: f64,
    pub coord_updates: u64,
    /// O(n) coordinate-gradient evaluations (the honest work metric).
    pub grad_evals: u64,
    pub outer_iters: usize,
    pub dual_obj: f64,
    /// Full-problem KKT violation (populated when `validate`).
    pub kkt_violation: Option<f64>,
}

impl StepRecord {
    /// Fraction of instances screened out at this step.
    pub fn rejection(&self, l: usize) -> f64 {
        (self.n_lo + self.n_hi) as f64 / l as f64
    }
}

/// Result of a full path run.
#[derive(Clone, Debug)]
pub struct PathOutput {
    pub dataset: String,
    pub model: Model,
    pub rule: RuleExpr,
    pub l: usize,
    pub steps: Vec<StepRecord>,
    /// Time solving the required initial point(s) — C₁ always; also C_K
    /// for SSNSV/ESSNSV (the paper's "Init." rows).
    pub init_secs: f64,
    /// Total screening time across the path (the paper's "DVI_s" rows).
    pub screen_secs: f64,
    /// Wall-clock for the whole run (init + screening + all solves).
    pub total_secs: f64,
    /// θ*(C_K) — the final model, for downstream use.
    pub final_theta: Vec<f64>,
}

impl PathOutput {
    /// Mean rejection over the screened steps (steps 2..K; the first grid
    /// point is always solved in full).
    pub fn mean_rejection(&self) -> f64 {
        let screened: Vec<f64> =
            self.steps.iter().skip(1).map(|s| s.rejection(self.l)).collect();
        crate::linalg::mean(&screened)
    }

    /// Rejection split per step (lo-fraction, hi-fraction) — the series
    /// behind the paper's stacked-area charts.
    pub fn rejection_series(&self) -> (Vec<f64>, Vec<f64>) {
        let l = self.l as f64;
        let r = self.steps.iter().map(|s| s.n_lo as f64 / l).collect();
        let h = self.steps.iter().map(|s| s.n_hi as f64 / l).collect();
        (r, h)
    }

    /// Total coordinate updates (solver work proxy).
    pub fn total_updates(&self) -> u64 {
        self.steps.iter().map(|s| s.coord_updates).sum()
    }

    /// Total coordinate-gradient evaluations — each costs an O(n) dot, so
    /// this is proportional to solver flops (the quantity screening cuts).
    pub fn total_grad_evals(&self) -> u64 {
        self.steps.iter().map(|s| s.grad_evals).sum()
    }

    /// Worst full-problem KKT violation observed (validation runs).
    pub fn worst_violation(&self) -> Option<f64> {
        self.steps.iter().filter_map(|s| s.kkt_violation).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.max(v)))
        })
    }
}

/// Orchestrates screen → reduce → solve along the grid. Screening goes
/// through the open [`ScreeningRule`] engine: single atoms run their
/// dedicated impls (bit-identical to the pre-refactor enum dispatch),
/// `+`-compositions intersect member regions.
pub struct PathRunner {
    pub model: Model,
    pub cfg: PathConfig,
    pub rule: RuleExpr,
    engine: Box<dyn ScreeningRule>,
}

impl PathRunner {
    /// Single-atom constructor (the legacy enum surface).
    /// `cfg.solver.threads` picks the w-form scan backend: 1 (the
    /// default) keeps the serial [`super::NativeScan`]; any other value
    /// installs the sharded [`super::ParScan`] (0 = auto-detect), whose
    /// decisions are byte-identical.
    pub fn new(model: Model, cfg: PathConfig, rule: RuleKind) -> PathRunner {
        Self::new_expr(model, cfg, RuleExpr::from_kind(rule))
    }

    /// Rule-expression constructor: atoms or `+`-compositions.
    pub fn new_expr(model: Model, cfg: PathConfig, rule: RuleExpr) -> PathRunner {
        let engine = rule.build_axis(cfg.solver.threads, cfg.solver.shard_axis);
        PathRunner { model, cfg, rule, engine }
    }

    /// Swap the DVI scan backend (e.g. the PJRT AOT executable). Only
    /// meaningful for the plain `dvi` rule — exactly the sites that
    /// installed one pre-refactor; other expressions keep their engine.
    pub fn with_backend(mut self, backend: Box<dyn DviScanBackend>) -> Self {
        if self.rule.single() == Some(RuleKind::DviW) {
            // re-wrap in the tracing decorator: backend swaps must not
            // silently drop screening spans/telemetry
            self.engine = Box::new(crate::screening::Traced::new(Box::new(
                DviWRule::with_backend(backend),
            )));
        }
        self
    }

    /// Run the full path on a dataset (constructs a transient instance;
    /// servers should prefer [`Self::run_shared`] over a cached one).
    pub fn run(&mut self, ds: &Dataset) -> PathOutput {
        let inst = Instance::from_dataset(self.model, ds);
        self.run_instance(&inst)
    }

    /// Run on a cache-resident instance: the runner only ever borrows, so
    /// an `Arc<Instance>` shared across concurrent jobs is never cloned —
    /// this is the entry point the coordinator's instance cache feeds.
    pub fn run_shared(&mut self, inst: &std::sync::Arc<Instance>) -> PathOutput {
        self.run_instance(inst)
    }

    /// Run on a pre-built (externally owned) instance.
    pub fn run_instance(&mut self, inst: &Instance) -> PathOutput {
        let grid = &self.cfg.grid;
        assert!(grid.len() >= 2, "need at least two grid points");
        assert!(
            grid.windows(2).all(|w| w[0] < w[1]),
            "grid must be strictly ascending"
        );
        let solver = CdSolver::new(self.cfg.solver.clone());
        let l = inst.len();
        let run_start = Instant::now();

        // --- init solves -------------------------------------------------
        let t = Instant::now();
        let mut cur = solver.solve(inst, grid[0], inst.cold_start());
        // keep the C₁ solve time separate: init_secs additionally absorbs
        // the SSNSV C_max solve and the DVI-θ Gram precompute below, and
        // charging those into steps[0].solve_secs would double-count init
        // work in the per-step table
        let c1_solve_secs = t.elapsed().as_secs_f64();
        let mut init_secs = c1_solve_secs;

        // The SSNSV family additionally requires the solution at C_max
        // (any composition with an ssnsv/essnsv member pays this too).
        let w_feasible: Option<Vec<f64>> = if self.rule.requires_cmax() {
            let t = Instant::now();
            let r = solver.solve(inst, *grid.last().unwrap(), inst.cold_start());
            init_secs += t.elapsed().as_secs_f64();
            Some(inst.w_from_theta_axis(
                *grid.last().unwrap(),
                &r.theta,
                self.cfg.solver.shard_axis,
                self.cfg.solver.threads,
            ))
        } else {
            None
        };

        // Per-instance rule precomputation — the θ-form's Gram matrix
        // build, a no-op for every other atom. Attributed to init (the
        // paper's "G can be computed only once").
        {
            let t = Instant::now();
            self.engine.init(inst, self.cfg.solver.threads);
            init_secs += t.elapsed().as_secs_f64();
        }

        let mut steps = Vec::with_capacity(grid.len());
        let mut screen_secs_total = 0.0;

        // first grid point: full solve, no screening
        steps.push(StepRecord {
            c: grid[0],
            n_lo: 0,
            n_hi: 0,
            free: l,
            screen_secs: 0.0,
            solve_secs: c1_solve_secs,
            coord_updates: cur.stats.coord_updates,
            grad_evals: cur.stats.grad_evals,
            outer_iters: cur.stats.outer_iters,
            dual_obj: inst.dual_objective(grid[0], &cur.theta),
            kkt_violation: self.cfg.validate.then(|| {
                CdSolver::kkt_violation_threads(inst, grid[0], &cur.theta, self.cfg.solver.threads)
            }),
        });

        // --- path --------------------------------------------------------
        for k in 1..grid.len() {
            let (c_prev, c_next) = (grid[k - 1], grid[k]);

            let mut step_span = crate::obs::Span::enter("path_step");
            step_span.attr("step", k as f64);
            step_span.attr("c", c_next);

            let t_screen = Instant::now();
            let report: ScreenReport = if self.rule.is_none() {
                ScreenReport::keep_all(l)
            } else {
                let ctx = StepContext {
                    c_prev,
                    c_next,
                    theta_prev: &cur.theta,
                    u_prev: &cur.u,
                    w_feasible: w_feasible.as_deref(),
                };
                let region = self.engine.prepare(inst, &ctx);
                ScreenReport::from_decisions(self.engine.screen_rows(
                    inst,
                    &region,
                    self.cfg.solver.threads,
                ))
            };
            let screen_secs = t_screen.elapsed().as_secs_f64();
            screen_secs_total += screen_secs;

            // Paper-protocol baseline: no warm start, every C solved
            // independently (only meaningful without screening).
            if self.rule.is_none() && !self.cfg.warm_start {
                let t_solve = Instant::now();
                cur = solver.solve(inst, c_next, inst.cold_start());
                steps.push(StepRecord {
                    c: c_next,
                    n_lo: 0,
                    n_hi: 0,
                    free: l,
                    screen_secs: 0.0,
                    solve_secs: t_solve.elapsed().as_secs_f64(),
                    coord_updates: cur.stats.coord_updates,
                    grad_evals: cur.stats.grad_evals,
                    outer_iters: cur.stats.outer_iters,
                    dual_obj: 0.5 * c_next * crate::linalg::norm_sq(&cur.u)
                        - crate::linalg::dot(&inst.ybar, &cur.theta),
                    kkt_violation: self.cfg.validate.then(|| {
                        CdSolver::kkt_violation_threads(
                            inst,
                            c_next,
                            &cur.theta,
                            self.cfg.solver.threads,
                        )
                    }),
                });
                continue;
            }

            // Warm start from the previous solution; snap screened coords
            // to their bound, updating u *incrementally* (only changed
            // coordinates pay) so the per-step cost is O(changed·n +
            // free·n·sweeps), never a blanket O(l·n).
            let mut theta0 = cur.theta.clone();
            let mut u0 = cur.u.clone();
            for (i, d) in report.decisions.iter().enumerate() {
                let target = match d {
                    crate::screening::Decision::AtLo => inst.lo[i],
                    crate::screening::Decision::AtHi => inst.hi[i],
                    crate::screening::Decision::Keep => {
                        crate::linalg::clamp(theta0[i], inst.lo[i], inst.hi[i])
                    }
                };
                let delta = target - theta0[i];
                if delta != 0.0 {
                    theta0[i] = target;
                    inst.z.row(i).axpy_into(delta, &mut u0);
                }
            }
            let free = report.free_indices();

            let t_solve = Instant::now();
            cur = {
                let mut sp = crate::obs::Span::enter("solve");
                sp.attr("free", free.len() as f64);
                solver.solve_free_with_u(inst, c_next, theta0, &free, u0)
            };
            let solve_secs = t_solve.elapsed().as_secs_f64();

            // periodic hygiene refresh of the incrementally-maintained u
            if k % 32 == 0 {
                cur.u = inst.u_from_theta_axis(
                    &cur.theta,
                    self.cfg.solver.shard_axis,
                    self.cfg.solver.threads,
                );
            }

            steps.push(StepRecord {
                c: c_next,
                n_lo: report.n_lo,
                n_hi: report.n_hi,
                free: free.len(),
                screen_secs,
                solve_secs,
                coord_updates: cur.stats.coord_updates,
                grad_evals: cur.stats.grad_evals,
                outer_iters: cur.stats.outer_iters,
                // O(n + l) from the cached u — NOT a fresh O(l·n) matvec
                dual_obj: 0.5 * c_next * crate::linalg::norm_sq(&cur.u)
                    - crate::linalg::dot(&inst.ybar, &cur.theta),
                kkt_violation: self.cfg.validate.then(|| {
                    CdSolver::kkt_violation_threads(
                        inst,
                        c_next,
                        &cur.theta,
                        self.cfg.solver.threads,
                    )
                }),
            });
        }

        PathOutput {
            dataset: inst.name.clone(),
            model: self.model,
            rule: self.rule.clone(),
            l,
            steps,
            init_secs,
            screen_secs: screen_secs_total,
            total_secs: run_start.elapsed().as_secs_f64(),
            final_theta: cur.theta,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn quick_cfg(points: usize) -> PathConfig {
        PathConfig::log_grid(1e-2, 10.0, points)
            .with_solver(SolverConfig { tol: 1e-7, max_outer: 50_000, ..Default::default() })
            .with_validation(true)
    }

    #[test]
    fn dvi_path_runs_and_is_safe() {
        let ds = synth::toy_gaussian(1, 150, 1.5, 0.75);
        let mut runner = PathRunner::new(Model::Svm, quick_cfg(12), RuleKind::DviW);
        let out = runner.run(&ds);
        assert_eq!(out.steps.len(), 12);
        // validation: the reduced solves still satisfy full-problem KKT
        let worst = out.worst_violation().unwrap();
        assert!(worst < 1e-5, "worst violation {worst}");
        // well-separated toy ⇒ strong screening
        assert!(out.mean_rejection() > 0.5, "rejection {}", out.mean_rejection());
    }

    #[test]
    fn lad_path_runs() {
        let mut rng = crate::data::Rng::new(10);
        let ds = synth::random_regression(&mut rng, 120, 5);
        // the paper's protocol uses a dense grid (100 pts); DVI's radius
        // shrinks with the grid spacing, so use a reasonably fine grid
        let mut runner = PathRunner::new(Model::Lad, quick_cfg(24), RuleKind::DviW);
        let out = runner.run(&ds);
        assert!(out.worst_violation().unwrap() < 1e-5);
        assert!(out.mean_rejection() > 0.1, "rejection {}", out.mean_rejection());
    }

    #[test]
    fn none_rule_keeps_everything() {
        let ds = synth::toy_gaussian(2, 60, 0.75, 0.75);
        let mut runner = PathRunner::new(Model::Svm, quick_cfg(5), RuleKind::None);
        let out = runner.run(&ds);
        assert_eq!(out.mean_rejection(), 0.0);
        assert!(out.worst_violation().unwrap() < 1e-5);
    }

    #[test]
    fn screened_path_matches_unscreened_path() {
        let ds = synth::toy_gaussian(3, 100, 1.0, 0.75);
        let cfg = quick_cfg(8);
        let out_dvi =
            PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW).run(&ds);
        let out_none = PathRunner::new(Model::Svm, cfg, RuleKind::None).run(&ds);
        for (a, b) in out_dvi.steps.iter().zip(&out_none.steps) {
            assert!(
                (a.dual_obj - b.dual_obj).abs() < 1e-5 * b.dual_obj.abs().max(1.0),
                "objective mismatch at C={}: {} vs {}",
                a.c,
                a.dual_obj,
                b.dual_obj
            );
        }
    }

    #[test]
    fn ssnsv_and_essnsv_paths_safe_and_ordered() {
        let ds = synth::toy_gaussian(4, 120, 1.0, 0.75);
        let cfg = quick_cfg(8);
        let out_s =
            PathRunner::new(Model::Svm, cfg.clone(), RuleKind::Ssnsv).run(&ds);
        let out_e =
            PathRunner::new(Model::Svm, cfg.clone(), RuleKind::Essnsv).run(&ds);
        let out_d = PathRunner::new(Model::Svm, cfg, RuleKind::DviW).run(&ds);
        assert!(out_s.worst_violation().unwrap() < 1e-5);
        assert!(out_e.worst_violation().unwrap() < 1e-5);
        // the paper's headline ordering: DVI ≥ ESSNSV ≥ SSNSV
        assert!(out_e.mean_rejection() >= out_s.mean_rejection() - 1e-12);
        assert!(out_d.mean_rejection() >= out_e.mean_rejection() - 1e-12);
    }

    #[test]
    fn dvi_theta_path_matches_w_path() {
        let ds = synth::toy_gaussian(5, 80, 1.0, 0.75);
        let cfg = quick_cfg(6);
        let a = PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW).run(&ds);
        let b = PathRunner::new(Model::Svm, cfg, RuleKind::DviTheta).run(&ds);
        for (x, y) in a.steps.iter().zip(&b.steps) {
            assert_eq!((x.n_lo, x.n_hi), (y.n_lo, y.n_hi), "at C={}", x.c);
        }
    }

    #[test]
    fn rejection_series_shapes() {
        let ds = synth::toy_gaussian(6, 60, 1.5, 0.75);
        let out = PathRunner::new(Model::Svm, quick_cfg(7), RuleKind::DviW).run(&ds);
        let (r, h) = out.rejection_series();
        assert_eq!(r.len(), 7);
        assert_eq!(h.len(), 7);
        assert!(r.iter().zip(&h).all(|(a, b)| a + b <= 1.0 + 1e-12));
    }

    #[test]
    fn composed_rule_path_safe_and_dominates_members() {
        let ds = synth::toy_gaussian(8, 120, 1.0, 0.75);
        let cfg = quick_cfg(8);
        let expr = crate::screening::RuleExpr::parse("dvi+essnsv").unwrap();
        let out_c = PathRunner::new_expr(Model::Svm, cfg.clone(), expr).run(&ds);
        // safe: the reduced solves still satisfy full-problem KKT
        assert!(out_c.worst_violation().unwrap() < 1e-5);
        assert_eq!(out_c.rule.name(), "dvi+essnsv");
        // at least as strong as each member over the whole path (both
        // trajectories coincide: screening is safe, so every rule's path
        // visits the same optima and the per-step contexts agree)
        let out_d =
            PathRunner::new(Model::Svm, cfg.clone(), RuleKind::DviW).run(&ds);
        let out_e = PathRunner::new(Model::Svm, cfg, RuleKind::Essnsv).run(&ds);
        assert!(out_c.mean_rejection() >= out_d.mean_rejection() - 1e-12);
        assert!(out_c.mean_rejection() >= out_e.mean_rejection() - 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_unsorted_grid() {
        let ds = synth::toy_gaussian(7, 20, 1.0, 0.75);
        let cfg = PathConfig {
            grid: vec![1.0, 0.5],
            solver: SolverConfig::default(),
            validate: false,
            warm_start: true,
        };
        PathRunner::new(Model::Svm, cfg, RuleKind::DviW).run(&ds);
    }
}
