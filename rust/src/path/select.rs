//! Model selection — the paper's §4 motivation for sequential screening:
//! "commonly used model selection strategies such as cross validation …
//! need to solve the optimization problems over a grid of tuning
//! parameters", which is exactly where DVI pays off.
//!
//! This module provides prediction from a path point and k-fold
//! cross-validation over the C-grid, with every fold's path screened.

use super::runner::{PathConfig, PathRunner};
use crate::data::{Dataset, Rng, Task};
use crate::problem::{Instance, Model};
use crate::screening::RuleKind;

/// Predict raw scores wᵀx for every instance.
pub fn scores(w: &[f64], ds: &Dataset) -> Vec<f64> {
    (0..ds.len()).map(|i| ds.x.row(i).dot(w)).collect()
}

/// Classification accuracy of sign(wᵀx) against ±1 labels.
pub fn accuracy(w: &[f64], ds: &Dataset) -> f64 {
    assert_eq!(ds.task, Task::Classification);
    if ds.is_empty() {
        return 0.0;
    }
    let correct = scores(w, ds)
        .iter()
        .zip(&ds.y)
        .filter(|(s, y)| **s * **y > 0.0)
        .count();
    correct as f64 / ds.len() as f64
}

/// Mean absolute error of wᵀx against regression targets.
pub fn mae(w: &[f64], ds: &Dataset) -> f64 {
    assert_eq!(ds.task, Task::Regression);
    if ds.is_empty() {
        return 0.0;
    }
    scores(w, ds)
        .iter()
        .zip(&ds.y)
        .map(|(s, y)| (s - y).abs())
        .sum::<f64>()
        / ds.len() as f64
}

/// Result of a cross-validated grid search.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// The grid (ascending C values).
    pub grid: Vec<f64>,
    /// Mean validation score per grid point (higher = better; accuracy
    /// for classification, −MAE for regression).
    pub mean_score: Vec<f64>,
    /// Index of the best grid point.
    pub best_index: usize,
    /// Total wall-clock over all folds.
    pub total_secs: f64,
    /// Mean rejection across folds (how much work screening saved).
    pub mean_rejection: f64,
}

impl CvResult {
    pub fn best_c(&self) -> f64 {
        self.grid[self.best_index]
    }
}

/// k-fold CV over the path: for each fold, run the screened path on the
/// training split and score w*(C) on the held-out split at every grid
/// point. Deterministic fold assignment from `seed`.
pub fn cross_validate(
    model: Model,
    ds: &Dataset,
    cfg: &PathConfig,
    rule: RuleKind,
    k: usize,
    seed: u64,
) -> CvResult {
    assert!(k >= 2, "need at least 2 folds");
    assert!(ds.len() >= 2 * k, "dataset too small for {k} folds");
    let t0 = std::time::Instant::now();
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    Rng::new(seed).shuffle(&mut idx);

    let points = cfg.grid.len();
    let mut score_sum = vec![0.0; points];
    let mut rejection_sum = 0.0;
    for fold in 0..k {
        let lo = fold * ds.len() / k;
        let hi = (fold + 1) * ds.len() / k;
        let val_idx = &idx[lo..hi];
        let train_idx: Vec<usize> =
            idx[..lo].iter().chain(idx[hi..].iter()).copied().collect();
        let train = ds.select(&train_idx);
        let val = ds.select(val_idx);

        // run the screened path on the training split, capturing w at
        // every grid point
        let inst = Instance::from_dataset(model, &train);
        let mut runner = PathRunner::new(model, cfg.clone(), rule);
        let out = runner.run_instance(&inst);
        rejection_sum += out.mean_rejection();
        // reconstruct w per step is not retained by PathOutput (it keeps
        // θ only for the final step), so re-derive from per-step θ via a
        // second pass: rerun capturing w. To avoid that cost we use the
        // recorded dual objective relation w = −C·u and recompute per
        // step from scratch... instead, simply run the path again with a
        // capture hook below.
        let ws = capture_path_ws(model, &inst, cfg, rule);
        for (p, w) in ws.iter().enumerate() {
            let s = match ds.task {
                Task::Classification => accuracy(w, &val),
                Task::Regression => -mae(w, &val),
            };
            score_sum[p] += s;
        }
    }
    let mean_score: Vec<f64> = score_sum.iter().map(|s| s / k as f64).collect();
    let best_index = mean_score
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    CvResult {
        grid: cfg.grid.clone(),
        mean_score,
        best_index,
        total_secs: t0.elapsed().as_secs_f64(),
        mean_rejection: rejection_sum / k as f64,
    }
}

/// Run a screened path capturing w*(C) at every grid point.
pub fn capture_path_ws(
    _model: Model,
    inst: &Instance,
    cfg: &PathConfig,
    rule: RuleKind,
) -> Vec<Vec<f64>> {
    use crate::screening::Dvi;
    use crate::solver::CdSolver;
    let solver = CdSolver::new(cfg.solver.clone());
    let dvi = Dvi::new_w();
    let mut ws = Vec::with_capacity(cfg.grid.len());
    let mut cur = solver.solve(inst, cfg.grid[0], inst.cold_start());
    ws.push(inst.w_from_theta(cfg.grid[0], &cur.theta));
    for k in 1..cfg.grid.len() {
        let (c_prev, c_next) = (cfg.grid[k - 1], cfg.grid[k]);
        let report = match rule {
            RuleKind::None => crate::screening::ScreenReport::keep_all(inst.len()),
            _ => dvi.screen(inst, c_prev, c_next, &cur.theta, &cur.u),
        };
        let mut theta0 = cur.theta.clone();
        report.apply_to_theta(inst, &mut theta0);
        cur = solver.solve_free(inst, c_next, theta0, &report.free_indices());
        ws.push(inst.w_from_theta(c_next, &cur.theta));
    }
    ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SolverConfig;
    use crate::data::synth;

    fn cfg(points: usize) -> PathConfig {
        PathConfig::log_grid(1e-2, 10.0, points)
            .with_solver(SolverConfig { tol: 1e-6, ..Default::default() })
    }

    #[test]
    fn metrics_basic() {
        use crate::data::Task;
        use crate::linalg::RowMatrix;
        let x = RowMatrix::from_flat(4, 1, vec![1.0, 2.0, -1.0, -3.0]);
        let ds = Dataset::new("m", Task::Classification, x, vec![1.0, 1.0, -1.0, 1.0]);
        assert!((accuracy(&[1.0], &ds) - 0.75).abs() < 1e-12);

        let xr = RowMatrix::from_flat(2, 1, vec![1.0, 2.0]);
        let dr = Dataset::new("r", Task::Regression, xr, vec![2.0, 2.0]);
        assert!((mae(&[1.0], &dr) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cv_selects_sensible_c_svm() {
        let ds = synth::toy_gaussian(71, 250, 1.0, 0.75);
        let r = cross_validate(Model::Svm, &ds, &cfg(12), RuleKind::DviW, 4, 7);
        assert_eq!(r.mean_score.len(), 12);
        // a separated-ish toy should be classifiable well above chance
        assert!(r.mean_score[r.best_index] > 0.85, "{:?}", r.mean_score);
        assert!(r.best_c() >= r.grid[0] && r.best_c() <= *r.grid.last().unwrap());
        assert!(r.mean_rejection > 0.0);
    }

    #[test]
    fn cv_screened_matches_unscreened_scores() {
        let ds = synth::toy_gaussian(72, 160, 1.0, 0.75);
        let a = cross_validate(Model::Svm, &ds, &cfg(8), RuleKind::DviW, 4, 3);
        let b = cross_validate(Model::Svm, &ds, &cfg(8), RuleKind::None, 4, 3);
        for (x, y) in a.mean_score.iter().zip(&b.mean_score) {
            assert!((x - y).abs() < 1e-9, "screening changed CV scores");
        }
        assert_eq!(a.best_index, b.best_index);
    }

    #[test]
    fn cv_regression_uses_neg_mae() {
        let mut rng = crate::data::Rng::new(9);
        let ds = synth::random_regression(&mut rng, 150, 4);
        let r = cross_validate(Model::Lad, &ds, &cfg(8), RuleKind::DviW, 3, 1);
        assert!(r.mean_score.iter().all(|&s| s <= 0.0));
        assert!(r.mean_score[r.best_index] > -10.0);
    }

    #[test]
    #[should_panic]
    fn cv_rejects_tiny_dataset() {
        let ds = synth::toy_gaussian(73, 3, 1.0, 0.75);
        cross_validate(Model::Svm, &ds, &cfg(4), RuleKind::DviW, 4, 1);
    }
}
