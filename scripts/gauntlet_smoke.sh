#!/usr/bin/env bash
# Gauntlet smoke test: drive a tiny rule grid through the built `dvi
# gauntlet` binary and hold BENCH_screening.json to its contract:
#
#   1. determinism — with --no-timings the benchmark is a pure function
#      of (datasets, rules, grid): two runs must emit identical bytes;
#   2. schema      — schema_version 1 with the documented dataset/rule
#      layout, validated structurally when python3 is available;
#   3. dominance   — every composed rule's per-step rejection rate is
#      >= the best of its members on every grid point (the composite
#      region is the members' intersection, so this is exact, not
#      statistical), and the emitter agrees via dominates_members;
#   4. timings     — a timed run adds the wall-clock fields without
#      perturbing the deterministic core.
set -euo pipefail
cd "$(dirname "$0")/.."

WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

cargo build --release --quiet
BIN=target/release/dvi

GAUNTLET_ARGS=(gauntlet --datasets toy1,toy2 --rules dvi,essnsv,dvi+essnsv
               --scale 0.02 --points 3 --tol 1e-4 --threads 2)

echo "== determinism: --no-timings double run must emit identical bytes"
"$BIN" "${GAUNTLET_ARGS[@]}" --no-timings --out "$WORK/run1" > /dev/null
"$BIN" "${GAUNTLET_ARGS[@]}" --no-timings --out "$WORK/run2" > /dev/null
test -s "$WORK/run1/BENCH_screening.json" || {
  echo "BENCH_screening.json was not written"; exit 1; }
diff "$WORK/run1/BENCH_screening.json" "$WORK/run2/BENCH_screening.json"

echo "== timed run still produces the benchmark (plus wall-clock fields)"
"$BIN" "${GAUNTLET_ARGS[@]}" --out "$WORK/timed" > /dev/null
grep -q '"scan_secs"' "$WORK/timed/BENCH_screening.json" || {
  echo "timed run is missing scan_secs"; exit 1; }

echo "== schema + dominance"
if command -v python3 > /dev/null; then
  python3 - "$WORK/run1/BENCH_screening.json" "$WORK/timed/BENCH_screening.json" <<'EOF'
import json, sys

for path, timed in [(sys.argv[1], False), (sys.argv[2], True)]:
    b = json.load(open(path))
    assert b["schema_version"] == 1, b["schema_version"]
    assert b["kind"] == "dvi-gauntlet", b["kind"]
    assert b["model"] == "svm"
    assert b["rules"] == ["dvi", "essnsv", "dvi+essnsv"]
    assert len(b["datasets"]) == 2, [d["dataset"] for d in b["datasets"]]
    for d in b["datasets"]:
        for key in ("dataset", "l", "n", "grid", "rules"):
            assert key in d, (d["dataset"], key)
        assert len(d["grid"]) == 3
        by_name = {r["rule"]: r for r in d["rules"]}
        assert set(by_name) == {"dvi", "essnsv", "dvi+essnsv"}
        for r in d["rules"]:
            steps = r["per_step_rejection"]
            assert len(steps) == len(d["grid"]) - 1, (r["rule"], len(steps))
            assert all(0.0 <= s <= 1.0 for s in steps), (r["rule"], steps)
            has_timing = "scan_secs" in r
            assert has_timing == timed, (path, r["rule"], sorted(r))
        both = by_name["dvi+essnsv"]
        assert both["dominates_members"] is True, both
        for k, c in enumerate(both["per_step_rejection"]):
            best = max(by_name["dvi"]["per_step_rejection"][k],
                       by_name["essnsv"]["per_step_rejection"][k])
            assert c >= best, (d["dataset"], k, c, best)
    print(f"   {path.split('/')[-2]}: schema + dominance OK")
EOF
else
  echo "   (python3 unavailable; grep-level checks only)"
  grep -q '"schema_version":1' "$WORK/run1/BENCH_screening.json"
  grep -q '"dominates_members":true' "$WORK/run1/BENCH_screening.json"
  if grep -q 'secs' "$WORK/run1/BENCH_screening.json"; then
    echo "--no-timings output leaked a wall-clock field"; exit 1
  fi
fi

echo "gauntlet smoke: OK"
