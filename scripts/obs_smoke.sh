#!/usr/bin/env bash
# Observability smoke test: hold the tracing + metrics surfaces to their
# contracts using the built `dvi` binary:
#
#   1. determinism — a scripted "timings": false stdin session produces
#                    byte-identical output with and without --trace-out;
#   2. trace shape — the written Chrome trace JSON loads, carries the
#                    required keys, sorts by ts, pairs every begin with
#                    its end (B/E and async b/e, keyed by args.id), and
#                    covers the whole lifecycle (connection -> request ->
#                    queue_wait -> job -> screen/sweep spans);
#   3. scrape      — `GET /metrics` on --metrics-listen answers valid
#                    Prometheus text (every sample typed, required
#                    families present) and non-/metrics paths 404;
#   4. SIGTERM     — a killed `dvi serve --listen --trace-out` server
#                    flushes its trace on the way down, and that trace
#                    passes the same shape validation.
#
# Requires python3 for the client / validators (present on CI runners).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v python3 > /dev/null; then
  echo "obs smoke: python3 unavailable; skipping"
  exit 0
fi

WORK=$(mktemp -d)
SERVER_PID=""
cleanup() {
  [[ -n "$SERVER_PID" ]] && kill "$SERVER_PID" 2> /dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

cargo build --release --quiet
BIN=target/release/dvi

cat > "$WORK/session.jsonl" <<'EOF'
{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "dvi", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 3, "rule": "dvi+essnsv", "tol": 1e-6, "timings": false}
{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.9]], "tol": 1e-6, "timings": false}
{"dataset": "no-such-set", "points": 4, "timings": false}
EOF

# Shared trace-shape validator (leg 2 and leg 4).
cat > "$WORK/check_trace.py" <<'EOF'
import json, sys

doc = json.load(open(sys.argv[1]))
assert "traceEvents" in doc and "displayTimeUnit" in doc, sorted(doc)
events = doc["traceEvents"]
assert events, "trace exported no spans"

ts = [e["ts"] for e in events]
assert all(a <= b for a, b in zip(ts, ts[1:])), "ts not monotone"

begins, ends = {}, {}
for i, e in enumerate(events):
    for key in ("name", "ph", "ts", "pid", "tid", "args"):
        assert key in e, (key, e)
    sid = e["args"]["id"]
    if e["ph"] in ("B", "b"):
        assert sid not in begins, f"duplicate begin {sid}"
        begins[sid] = i
    elif e["ph"] in ("E", "e"):
        assert sid in begins, f"end before begin {sid}"
        assert sid not in ends, f"duplicate end {sid}"
        ends[sid] = i
    else:
        raise AssertionError(f"unexpected phase {e['ph']}")
    if e["ph"] in ("b", "e"):  # async events need the matching id + cat
        assert e.get("cat") == "request" and e.get("id"), e
assert set(begins) == set(ends), "unpaired spans escaped the exporter"

names = {e["name"] for e in events}
for want in sys.argv[2:]:
    assert want in names, f"span `{want}` missing from {sorted(names)}"
print(f"   trace OK: {len(events)} events, {len(begins)} spans, names {sorted(names)}")
EOF

# Prometheus text-format validator.
cat > "$WORK/check_metrics.py" <<'EOF'
import re, sys

body = open(sys.argv[1]).read()
typed, samples = {}, 0
for line in body.splitlines():
    if not line.strip():
        continue
    if line.startswith("# TYPE "):
        _, _, name, kind = line.split()
        assert kind in ("counter", "gauge", "summary"), line
        typed[name] = kind
        continue
    if line.startswith("#"):
        continue
    m = re.match(r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})? (-?[0-9.eE+-]+|NaN|\+Inf|-Inf)$', line)
    assert m, f"bad sample line: {line!r}"
    base = re.sub(r"_(sum|count)$", "", m.group(1))
    assert m.group(1) in typed or base in typed, f"untyped sample: {line!r}"
    samples += 1
assert samples > 0, "no samples rendered"
for fam in sys.argv[2:]:
    assert fam in body, f"family `{fam}` missing from scrape:\n{body}"
print(f"   metrics OK: {samples} samples, {len(typed)} typed families")
EOF

# One-shot TCP client: send a session, half-close, drain to EOF.
cat > "$WORK/client.py" <<'EOF'
import socket, sys
host, port, infile, outfile = sys.argv[1], int(sys.argv[2]), sys.argv[3], sys.argv[4]
s = socket.create_connection((host, port), timeout=120)
with open(infile, "rb") as f:
    s.sendall(f.read())
s.shutdown(socket.SHUT_WR)
chunks = []
while True:
    c = s.recv(65536)
    if not c:
        break
    chunks.append(c)
with open(outfile, "wb") as f:
    f.write(b"".join(chunks))
EOF

echo "== traced stdin session is byte-identical to the untraced one"
"$BIN" serve --workers 3 < "$WORK/session.jsonl" > "$WORK/out.plain" 2> /dev/null
"$BIN" serve --workers 3 --trace-out "$WORK/stdin.trace.json" \
  < "$WORK/session.jsonl" > "$WORK/out.traced" 2> /dev/null
diff "$WORK/out.plain" "$WORK/out.traced"

echo "== the stdin trace is well-formed Chrome trace JSON"
python3 "$WORK/check_trace.py" "$WORK/stdin.trace.json" \
  connection request queue_wait job sweep screen_rows

echo "== serve --metrics-listen answers a valid Prometheus scrape"
"$BIN" serve --workers 3 --listen 127.0.0.1:0 --metrics-listen 127.0.0.1:0 \
  --trace-out "$WORK/net.trace.json" 2> "$WORK/serve.log" &
SERVER_PID=$!
PORT="" MPORT=""
for _ in $(seq 1 100); do
  PORT=$(sed -n 's/.*\[serve\] listening on 127\.0\.0\.1:\([0-9]*\)$/\1/p' "$WORK/serve.log" | head -1)
  MPORT=$(sed -n 's/.*\[serve\] metrics listening on 127\.0\.0\.1:\([0-9]*\)$/\1/p' "$WORK/serve.log" | head -1)
  [[ -n "$PORT" && -n "$MPORT" ]] && break
  kill -0 "$SERVER_PID" 2> /dev/null || { echo "server died:"; cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[[ -n "$PORT" && -n "$MPORT" ]] || { echo "server never bound:"; cat "$WORK/serve.log"; exit 1; }

python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/session.jsonl" "$WORK/out.net"
diff "$WORK/out.plain" "$WORK/out.net"

curl -sf "http://127.0.0.1:$MPORT/metrics" > "$WORK/scrape.txt" \
  || python3 -c "import sys,urllib.request;open(sys.argv[2],'wb').write(urllib.request.urlopen(sys.argv[1]).read())" \
       "http://127.0.0.1:$MPORT/metrics" "$WORK/scrape.txt"
python3 "$WORK/check_metrics.py" "$WORK/scrape.txt" \
  jobs_done service_requests serve_inflight serve_dispatcher_backlog \
  serve_request_secs pool_queue_depth pool_workers_spawned_total \
  'screen_rows_scanned_total{rule="dvi"}'
if python3 -c "import sys,urllib.request,urllib.error
try:
    urllib.request.urlopen(sys.argv[1])
except urllib.error.HTTPError as e:
    sys.exit(0 if e.code == 404 else 1)
sys.exit(1)" "http://127.0.0.1:$MPORT/other"; then
  echo "   non-/metrics paths answer 404"
else
  echo "expected 404 for /other"; exit 1
fi

echo "== SIGTERM flushes the server trace"
kill -TERM "$SERVER_PID"
for _ in $(seq 1 100); do
  kill -0 "$SERVER_PID" 2> /dev/null || break
  sleep 0.1
done
kill -0 "$SERVER_PID" 2> /dev/null && { echo "server ignored SIGTERM"; exit 1; }
SERVER_PID=""
[[ -s "$WORK/net.trace.json" ]] || { echo "no trace flushed on SIGTERM:"; cat "$WORK/serve.log"; exit 1; }
python3 "$WORK/check_trace.py" "$WORK/net.trace.json" \
  connection request queue_wait job sweep screen_rows

echo "obs smoke: OK"
