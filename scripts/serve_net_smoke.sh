#!/usr/bin/env bash
# Network serving smoke test: run the built `dvi serve --listen` binary
# on a loopback port and hold the serving subsystem to its contracts:
#
#   1. network ≡ stdin — two CONCURRENT scripted TCP clients each get
#                     byte-for-byte the output the same session produces
#                     through the stdin adapter ("timings": false);
#   2. stream ≡ buffered — a `"stream": true` session's lines re-sorted
#                     by id are byte-identical to the buffered session;
#   3. registry restart — a model trained with "persist": true lands in
#                     --model-dir; a RESTARTED server loads it at startup
#                     and serves predict by model_id with zero retrains
#                     (asserted on the "stats" counters: a model-cache
#                     hit, no artifact re-read, one registry load);
#   4. SIGTERM drain — a TERMed server stops admitting (typed
#                     "code": "draining" refusals on a live connection),
#                     finishes what it already accepted, and exits 0 on
#                     its own instead of needing SIGKILL.
#
# Requires python3 for the TCP clients (present on the CI runners).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v python3 > /dev/null; then
  echo "serve net smoke: python3 unavailable; skipping"
  exit 0
fi

WORK=$(mktemp -d)
SERVER_PID=""
cleanup() {
  [[ -n "$SERVER_PID" ]] && kill "$SERVER_PID" 2> /dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

cargo build --release --quiet
BIN=target/release/dvi
MODELDIR="$WORK/models"

# A deterministic all-single-request session (every response line
# carries an id, so the streamed sort in leg 2 is total).
cat > "$WORK/session.jsonl" <<'EOF'
{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "dvi", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 4, "rule": "essnsv", "tol": 1e-6, "timings": false}
{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.8], [0.8, 1.6]], "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 3, "rule": "none", "tol": 1e-6, "timings": false}
{"dataset": "no-such-set", "points": 4, "timings": false}
EOF
sed 's/^{/{"stream": true, /' "$WORK/session.jsonl" > "$WORK/session.stream.jsonl"

# One-shot TCP client: send a session, half-close, drain to EOF.
cat > "$WORK/client.py" <<'EOF'
import socket, sys
host, port, infile, outfile = sys.argv[1], int(sys.argv[2]), sys.argv[3], sys.argv[4]
s = socket.create_connection((host, port), timeout=120)
with open(infile, "rb") as f:
    s.sendall(f.read())
s.shutdown(socket.SHUT_WR)
chunks = []
while True:
    c = s.recv(65536)
    if not c:
        break
    chunks.append(c)
with open(outfile, "wb") as f:
    f.write(b"".join(chunks))
EOF

start_server() {  # start_server <logfile> [extra serve flags...]
  local log=$1; shift
  "$BIN" serve --workers 3 --listen 127.0.0.1:0 "$@" 2> "$log" &
  SERVER_PID=$!
  local port=""
  for _ in $(seq 1 100); do
    port=$(sed -n 's/.*\[serve\] listening on 127\.0\.0\.1:\([0-9]*\)$/\1/p' "$log" | head -1)
    [[ -n "$port" ]] && break
    kill -0 "$SERVER_PID" 2> /dev/null || { echo "server died:"; cat "$log"; exit 1; }
    sleep 0.1
  done
  [[ -n "$port" ]] || { echo "server never bound:"; cat "$log"; exit 1; }
  PORT=$port
}

stop_server() {
  kill "$SERVER_PID" 2> /dev/null || true
  wait "$SERVER_PID" 2> /dev/null || true
  SERVER_PID=""
}

# The stdin adapter is the byte reference for every network client.
"$BIN" serve --workers 3 < "$WORK/session.jsonl" > "$WORK/ref.buffered" 2> /dev/null

start_server "$WORK/serve1.log" --model-dir "$MODELDIR"

echo "== two concurrent TCP clients, each byte-identical to stdin serve"
python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/session.jsonl" "$WORK/out.client1" &
C1=$!
python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/session.jsonl" "$WORK/out.client2" &
C2=$!
wait "$C1" "$C2"
diff "$WORK/ref.buffered" "$WORK/out.client1"
diff "$WORK/ref.buffered" "$WORK/out.client2"

echo "== streamed output re-sorted by id diffs clean against buffered"
python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/session.stream.jsonl" "$WORK/out.stream"
python3 - "$WORK/out.stream" <<'EOF' > "$WORK/out.stream.sorted"
import json, sys
lines = [l for l in open(sys.argv[1]) if l.strip()]
lines.sort(key=lambda l: json.loads(l)["id"])
sys.stdout.write("".join(lines))
EOF
diff "$WORK/ref.buffered" "$WORK/out.stream.sorted"

echo "== train with persist:true writes into --model-dir"
cat > "$WORK/train.jsonl" <<'EOF'
{"kind": "train", "dataset": "toy1", "scale": 0.05, "c": 0.5, "tol": 1e-6, "persist": true, "timings": false}
EOF
python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/train.jsonl" "$WORK/out.train"
grep -q '"ok":true' "$WORK/out.train" || { echo "train failed:"; cat "$WORK/out.train"; exit 1; }
MODEL_ID=$(python3 -c 'import json,sys; print(json.load(open(sys.argv[1]))["model_id"])' "$WORK/out.train")
ls "$MODELDIR/$MODEL_ID.pallas-model" > /dev/null

stop_server

echo "== a restarted server loads the registry and predicts with zero retrains"
# a corrupt artifact next to the good one must be skipped, not fatal
printf 'PALLASMD garbage' > "$MODELDIR/junk.pallas-model"
start_server "$WORK/serve2.log" --model-dir "$MODELDIR"
grep -q "model-dir: loaded $MODEL_ID" "$WORK/serve2.log" || {
  echo "expected a registry load log line:"; cat "$WORK/serve2.log"; exit 1; }
grep -q "model-dir: skipped .*junk" "$WORK/serve2.log" || {
  echo "expected the corrupt artifact to be skipped:"; cat "$WORK/serve2.log"; exit 1; }
cat > "$WORK/predict.jsonl" <<EOF
{"kind": "predict", "model_id": "$MODEL_ID", "dataset": "toy1", "scale": 0.05, "timings": false}
{"kind": "stats", "timings": false}
EOF
python3 "$WORK/client.py" 127.0.0.1 "$PORT" "$WORK/predict.jsonl" "$WORK/out.predict"
python3 - "$WORK/out.predict" <<'EOF'
import json, sys
predict, stats = [json.loads(l) for l in open(sys.argv[1]) if l.strip()]
assert predict["ok"], predict
c = stats["counters"]
assert c.get("model_registry_loaded") == 1, c
assert c.get("model_registry_skipped") == 1, c
assert c.get("model_cache_hits") == 1, c
assert "model_cache_loads" not in c, c
print(f"   predict served {predict['rows']} rows from the restarted registry")
EOF
stop_server

echo "== SIGTERM drains: typed refusals for new work, in-flight flushed, exit 0"
start_server "$WORK/serve3.log"
cat > "$WORK/drain.py" <<'EOF'
import json, os, signal, socket, sys, threading, time
host, port, pid = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
s = socket.create_connection((host, port), timeout=120)
f = s.makefile("rb")
def send(obj):
    s.sendall((json.dumps(obj) + "\n").encode())
lines = []
def reader():
    while True:
        l = f.readline()
        if not l:
            break
        lines.append(json.loads(l))
t = threading.Thread(target=reader)
t.start()
# the connection serves normally before the drain
send({"stream": True, "kind": "stats", "timings": False})
for _ in range(100):
    if lines:
        break
    time.sleep(0.05)
assert lines and lines[0].get("ok") is True, lines
# occupy the pool so the drain has in-flight work to wait for, then TERM
send({"stream": True, "dataset": "toy2", "scale": 0.5, "points": 8,
      "timings": False})
os.kill(pid, signal.SIGTERM)
# probe the SAME live connection until the draining refusal lands
for _ in range(200):
    if any(l.get("code") == "draining" for l in lines):
        break
    try:
        send({"stream": True, "kind": "stats", "timings": False})
    except OSError:
        break
    time.sleep(0.05)
t.join(timeout=60)
refused = [l for l in lines if l.get("code") == "draining"]
flushed = [l for l in lines if l.get("ok") is True and "steps" in l]
assert refused, lines
assert all("id" not in r for r in refused), lines
assert flushed, lines
print("   drain refused %d probe(s), flushed the in-flight path run"
      % len(refused))
EOF
python3 "$WORK/drain.py" 127.0.0.1 "$PORT" "$SERVER_PID"
# the TERMed server must exit 0 on its own — no SIGKILL escalation
for _ in $(seq 1 100); do
  kill -0 "$SERVER_PID" 2> /dev/null || break
  sleep 0.1
done
if kill -0 "$SERVER_PID" 2> /dev/null; then
  echo "server survived SIGTERM past the drain deadline:"; cat "$WORK/serve3.log"; exit 1
fi
wait "$SERVER_PID" 2> /dev/null && RC=0 || RC=$?
[[ "$RC" -eq 0 ]] || { echo "drained server exited $RC:"; cat "$WORK/serve3.log"; exit 1; }
grep -q "SIGTERM: draining" "$WORK/serve3.log" || {
  echo "expected a drain log line:"; cat "$WORK/serve3.log"; exit 1; }
SERVER_PID=""

echo "serve net smoke: OK"
