#!/usr/bin/env bash
# Solver bench smoke: run the solver-focused bench_micro series (cd
# sweep scaling, cd-mode sync vs async, pool reuse) at a CI-sized l and
# hold BENCH_solver.json to its contract:
#
#   1. schema   — schema_version 1 with the cd_sweep / cd_mode /
#                 pool_reuse series present;
#   2. scaling  — on the LARGEST l in the run, the 4-thread sync sweep
#                 must reach >= MIN_SPEEDUP x the serial sweep (the
#                 tentpole's perf floor; ~2x expected, gated at 1.8 for
#                 CI-runner noise, overridable via BENCH_MIN_SPEEDUP);
#   3. pool     — the persistent pool spawns at most one worker per
#                 shard slot across the whole run (i.e. <= 1 spawn per
#                 solve, amortized ~0), while the scoped fallback spawns
#                 per call;
#   4. modes    — every cd_mode cell converged (asserted inside the
#                 bench itself) and both modes report wall-clock;
#   5. axis     — on the widest shard_axis cells (largest n), the `auto`
#                 axis must stay within 10% of the better fixed axis
#                 (rows vs cols) — the auto heuristic may never cost
#                 more than picking the worse axis saves. Gated only on
#                 >= 4 cores (the cells race 4-way sharding).
#
# CI runners expose few cores; the gate reads the machine's parallelism
# first and SKIPS the speedup assertion (not the run) below 4 cores.
set -euo pipefail
cd "$(dirname "$0")/.."

WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

# 100k rows keeps the full series under a couple of minutes in release
# while staying big enough for the 4-thread sweep to beat spawn overhead
MAX_L=${BENCH_MAX_L:-100000}
MIN_SPEEDUP=${BENCH_MIN_SPEEDUP:-1.8}

cargo build --release --quiet --benches
cargo bench --bench bench_micro -- --max-l "$MAX_L" --out "$WORK" | tail -n 40

test -s "$WORK/BENCH_solver.json" || {
  echo "BENCH_solver.json was not written"; exit 1; }

if command -v python3 > /dev/null; then
  python3 - "$WORK/BENCH_solver.json" "$MIN_SPEEDUP" <<'EOF'
import json, os, sys

b = json.load(open(sys.argv[1]))
min_speedup = float(sys.argv[2])
assert b["schema_version"] == 1, b["schema_version"]
series = b["series"]
kinds = {e["series"] for e in series}
assert {"cd_sweep", "cd_mode", "pool_reuse", "shard_axis"} <= kinds, sorted(kinds)

# -- scaling gate: 4-thread sync >= MIN_SPEEDUP x serial on the largest l
sweeps = [e for e in series if e["series"] == "cd_sweep"]
big = max(e["l"] for e in sweeps)
cores = os.cpu_count() or 1
checked = 0
for arm in ("full", "screened"):
    cells = {e["threads"]: e for e in sweeps
             if e["l"] == big and e["arm"] == arm and e["storage"] == "dense"}
    if 1 not in cells or 4 not in cells:
        continue
    x = cells[1]["min_s"] / cells[4]["min_s"]
    print(f"   cd_sweep dense l={big} {arm}: 4-thread sync = {x:.2f}x serial")
    if cores >= 4:
        assert x >= min_speedup, (
            f"{arm}: 4-thread sync only {x:.2f}x serial on l={big} "
            f"(gate {min_speedup}x, {cores} cores)")
        checked += 1
if cores >= 4:
    assert checked > 0, "no l=100k dense cells found to gate on"
else:
    print(f"   ({cores} cores: speedup gate skipped, series still ran)")

# -- pool accounting: persistent workers, not per-call spawns
pool = {e["kind"]: e for e in series if e["series"] == "pool_reuse"}
routed, scoped = pool["routed"], pool["scoped"]
assert routed["workers_spawned"] <= routed["threads"], routed
spawn_per_call = routed["workers_spawned"] / max(routed["iters"], 1)
assert spawn_per_call <= 1.0, routed
assert scoped["os_threads_spawned"] >= scoped["iters"], scoped
print(f"   pool: {routed['workers_spawned']} spawns over {routed['iters']} calls "
      f"vs scoped {scoped['os_threads_spawned']} over {scoped['iters']}")

# -- shard-axis gate: auto within 10% of the better fixed axis, widest n
axes = [e for e in series if e["series"] == "shard_axis"]
wide_n = max(e["n"] for e in axes)
for storage in ("dense", "csr"):
    cell = {e["axis"]: e for e in axes
            if e["n"] == wide_n and e["storage"] == storage}
    if {"rows", "cols", "auto"} - set(cell):
        continue
    best = min(cell["rows"]["min_s"], cell["cols"]["min_s"])
    ratio = cell["auto"]["min_s"] / best
    picked = cell["auto"]["picked"]
    print(f"   shard_axis {storage} n={wide_n}: auto({picked}) = "
          f"{ratio:.2f}x the better fixed axis")
    if cores >= 4:
        assert ratio <= 1.10, (
            f"{storage} n={wide_n}: auto axis ({picked}) is {ratio:.2f}x the "
            f"better fixed axis (gate 1.10x, {cores} cores)")

# -- cd_mode series shape: sync & async rows for every (l, storage)
modes = [e for e in series if e["series"] == "cd_mode"]
assert {e["mode"] for e in modes} == {"sync", "async"}, modes
for e in modes:
    assert e["min_s"] > 0, e
print("   BENCH_solver.json: schema + gates OK")
EOF
else
  echo "   (python3 unavailable; grep-level checks only)"
  grep -q '"schema_version":1' "$WORK/BENCH_solver.json"
  grep -q '"series":"cd_mode"' "$WORK/BENCH_solver.json"
  grep -q '"series":"pool_reuse"' "$WORK/BENCH_solver.json"
fi

echo "bench smoke: OK"
