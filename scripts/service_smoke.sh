#!/usr/bin/env bash
# Service smoke test: pipe a scripted batch session through `dvi serve`
# and hold the responses to the protocol's defining invariants:
#
#   1. determinism  — the same session served twice yields byte-identical
#                     output (responses use "timings": false);
#   2. batch ≡ singles — the {"batch": [...]} response contains exactly
#                     the objects the same requests produce as
#                     independent lines (checked with python3 when
#                     available);
#   3. golden diff  — if examples/service_smoke.golden exists, the batch
#                     session's output must match it byte for byte.
#                     Regenerate with `scripts/service_smoke.sh --bless`
#                     after an intentional protocol change.
#   4. model loop   — `dvi train` writes a .pallas-model artifact, the
#                     service's "kind": "predict" serves it (double-run
#                     determinism diff), and `dvi predict` emits the same
#                     scores the service returns.
#   5. parallel CD  — `dvi train --solver-threads 4` classifies the exact
#                     support set the serial solver does (the sharded
#                     sweep's decision-equivalence contract, end to end).
#
# The screening_service example runs last as an end-to-end sanity check
# (it asserts its own expectations internally).
set -euo pipefail
cd "$(dirname "$0")/.."

GOLDEN=examples/service_smoke.golden
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

cargo build --release --quiet
BIN=target/release/dvi

# The scripted session: three same-dataset path runs (one construction —
# the cache test), a screen job, a job error, and a parse error. All
# deterministic.
cat > "$WORK/singles.jsonl" <<'EOF'
{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "dvi", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "essnsv", "tol": 1e-6, "timings": false}
{"dataset": "toy1", "scale": 0.05, "points": 5, "rule": "none", "tol": 1e-6, "timings": false}
{"kind": "screen", "dataset": "toy1", "scale": 0.05, "pairs": [[0.5, 0.8], [0.8, 1.6]], "tol": 1e-6, "timings": false}
{"dataset": "no-such-set", "points": 4, "timings": false}
{"dataset": "toy1", "points": 0}
EOF
# the same six requests as one batch line
awk 'BEGIN{printf "{\"batch\": ["} {printf "%s%s", (NR>1?", ":""), $0} END{print "]}"}' \
  "$WORK/singles.jsonl" > "$WORK/batch.jsonl"

run_serve() { "$BIN" serve --workers 3 < "$1" 2> "$WORK/metrics.$2"; }

run_serve "$WORK/batch.jsonl"   batch1 > "$WORK/out.batch1"
run_serve "$WORK/batch.jsonl"   batch2 > "$WORK/out.batch2"
run_serve "$WORK/singles.jsonl" single > "$WORK/out.singles"

echo "== determinism: identical sessions must serve identical bytes"
diff "$WORK/out.batch1" "$WORK/out.batch2"

echo "== cache: the batch names one dataset -> exactly one construction"
grep -q "^instance_cache_misses = 1$" "$WORK/metrics.batch1" || {
  echo "expected instance_cache_misses = 1:"; cat "$WORK/metrics.batch1"; exit 1; }

echo "== batch entries must equal the independent single-line responses"
if command -v python3 > /dev/null; then
  python3 - "$WORK/out.batch1" "$WORK/out.singles" <<'EOF'
import json, sys
batch = json.load(open(sys.argv[1]))["batch"]
singles = [json.loads(l) for l in open(sys.argv[2]) if l.strip()]
assert len(batch) == len(singles), (len(batch), len(singles))
for i, (b, s) in enumerate(zip(batch, singles)):
    assert b == s, f"entry {i} diverged:\n batch: {b}\n single: {s}"
print(f"   {len(batch)} entries identical")
EOF
else
  echo "   (python3 unavailable; skipping structural comparison)"
fi

if [[ "${1:-}" == "--bless" ]]; then
  cp "$WORK/out.batch1" "$GOLDEN"
  echo "== blessed $GOLDEN"
elif [[ -f "$GOLDEN" ]]; then
  echo "== golden diff"
  diff "$GOLDEN" "$WORK/out.batch1"
else
  echo "== no $GOLDEN committed yet; run with --bless to create it"
fi

echo "== model artifacts: train -> predict round trip"
MODEL="$WORK/smoke.pallas-model"
cat > "$WORK/train.jsonl" <<EOF
{"kind": "train", "dataset": "toy1", "model": "svm", "scale": 0.05, "c": 0.5, "tol": 1e-6, "save": "$MODEL", "timings": false}
EOF
cat > "$WORK/predict.jsonl" <<EOF
{"kind": "predict", "model_file": "$MODEL", "dataset": "toy1", "scale": 0.05, "timings": false}
{"kind": "predict", "model_file": "$MODEL", "dataset": "toy1", "scale": 0.05, "support_only": true, "timings": false}
{"kind": "predict", "model_file": "$MODEL", "rows": [[0.0, 0.0], [1.5, 1.5], [-1.5, -1.5]], "timings": false}
EOF
"$BIN" serve --workers 2 < "$WORK/train.jsonl" > "$WORK/out.train" 2> /dev/null
grep -q '"ok":true' "$WORK/out.train" || { echo "train failed:"; cat "$WORK/out.train"; exit 1; }
test -s "$MODEL" || { echo "artifact $MODEL was not written"; exit 1; }
"$BIN" serve --workers 3 < "$WORK/predict.jsonl" > "$WORK/out.predict1" 2> /dev/null
"$BIN" serve --workers 3 < "$WORK/predict.jsonl" > "$WORK/out.predict2" 2> /dev/null
echo "   predict double-run determinism"
diff "$WORK/out.predict1" "$WORK/out.predict2"
if grep -q '"ok":false' "$WORK/out.predict1"; then
  echo "a predict request failed:"; cat "$WORK/out.predict1"; exit 1
fi

echo "== cli predict agrees with the service (and with itself)"
"$BIN" predict --model "$MODEL" --dataset toy1 --scale 0.05 > "$WORK/cli.scores1"
"$BIN" predict --model "$MODEL" --dataset toy1 --scale 0.05 --threads 4 --support-only > "$WORK/cli.scores2"
diff "$WORK/cli.scores1" "$WORK/cli.scores2"
if command -v python3 > /dev/null; then
  python3 - "$WORK/out.predict1" "$WORK/cli.scores1" <<'EOF'
import json, sys
service = json.loads(open(sys.argv[1]).readline())["scores"]
cli = [float(l) for l in open(sys.argv[2]) if l.strip()]
assert len(service) == len(cli), (len(service), len(cli))
for i, (a, b) in enumerate(zip(service, cli)):
    assert a == b, f"score {i} diverged: service {a!r} vs cli {b!r}"
print(f"   {len(cli)} scores identical")
EOF
else
  echo "   (python3 unavailable; skipping service-vs-cli score comparison)"
fi

# Note: the E-set dead band equals the solve tol, so only a data point
# whose TRUE margin sits within ~tol (1e-8) of the band edge could
# classify differently between the two solvers — toy1 is a fixed generic
# Gaussian set with no such degenerate margin, so the exact diff is
# stable. (integration_cd_par.rs covers the general case with a band
# 1000x the solve tol.)
echo "== parallel CD: --solver-threads 4 trains the serial support set"
"$BIN" train --dataset toy1 --scale 0.05 --c 0.5 --tol 1e-8 --print-support \
  > "$WORK/train.serial"
"$BIN" train --dataset toy1 --scale 0.05 --c 0.5 --tol 1e-8 --print-support \
  --solver-threads 4 > "$WORK/train.par"
grep '^support_indices=' "$WORK/train.serial" > "$WORK/support.serial"
grep '^support_indices=' "$WORK/train.par"    > "$WORK/support.par"
test -s "$WORK/support.serial" || { echo "no support set printed:"; cat "$WORK/train.serial"; exit 1; }
diff "$WORK/support.serial" "$WORK/support.par"

echo "== cache introspection lists the preloaded instance"
"$BIN" serve --workers 1 --preload toy1 --preload-scale 0.05 \
  <<< '{"kind": "cache", "timings": false}' > "$WORK/out.cache" 2> "$WORK/metrics.cache"
grep -q '"dataset":"toy1"' "$WORK/out.cache" || {
  echo "expected the preloaded toy1 entry:"; cat "$WORK/out.cache"; exit 1; }
grep -q "preloaded toy1" "$WORK/metrics.cache" || {
  echo "expected a preload log line:"; cat "$WORK/metrics.cache"; exit 1; }

echo "== stats snapshot covers every metrics family"
printf '%s\n%s\n' \
  '{"kind": "stats", "timings": false}' \
  '{"kind": "stats"}' \
  | "$BIN" serve --workers 1 > "$WORK/out.stats" 2> /dev/null
head -1 "$WORK/out.stats" | grep -q '"kind":"stats"' || {
  echo "expected a stats response:"; cat "$WORK/out.stats"; exit 1; }
for fam in counters gauges pool; do
  head -1 "$WORK/out.stats" | grep -q "\"$fam\"" || {
    echo "stats snapshot is missing \"$fam\":"; cat "$WORK/out.stats"; exit 1; }
done
# histograms are wall-clock derived: absent under "timings": false,
# present in the default (timed) snapshot
if head -1 "$WORK/out.stats" | grep -q '"histograms"'; then
  echo "deterministic stats must omit histograms:"; cat "$WORK/out.stats"; exit 1
fi
tail -1 "$WORK/out.stats" | grep -q '"histograms"' || {
  echo "timed stats must include histograms:"; cat "$WORK/out.stats"; exit 1; }

echo "== screening_service example"
cargo run --release --quiet --example screening_service > /dev/null

echo "service smoke: OK"
